//! The `mgardp serve` daemon: concurrent error-bounded retrieval.
//!
//! One [`Server`] owns one progressively refactored field (over any
//! [`crate::storage::Storage`] backend) and answers simultaneous clients
//! over plain TCP — no external crates. Connections are serviced by a
//! **bounded worker pool** ([`crate::chunk::WorkerPool`]): at most
//! `max_connections` are in service at once, at most `queue_depth` more
//! wait for a worker, and anything beyond that is refused immediately
//! with a structured `Busy` frame instead of hanging or resetting. All
//! connections share one byte-capacity [`ComponentCache`] with
//! single-flight miss de-duplication, so the hot prefix components (sign
//! planes, high bitplanes) are fetched from the backend once — even
//! under a stampede of concurrent cold clients — and then served from
//! memory; per-connection **fetch state** (components already served on
//! that connection) lets a `plan` request with no explicit floor return
//! exactly the delta the client still needs.
//!
//! Every request gets a deadline of `request_timeout_ms` from the moment
//! its frame arrives, threaded through the storage retry loop
//! ([`crate::storage::with_retries_until`]) and checked between
//! component fetches — a slow backend cannot wedge a worker for longer
//! than one backend operation past the deadline. An expired request is
//! answered with a `Deadline` frame and the connection stays usable.
//!
//! Shutdown is cooperative: the `shutdown` op (or [`Server::stop`]) sets
//! a flag and wakes the accept loop with a loopback connection. Workers
//! poll the flag while waiting for frames (50 ms granularity), so every
//! worker drains even when clients sit idle on open connections.

use super::protocol::{
    busy_response, deadline_response, encode_plan, err_response, ok_response, put_f64, put_u64,
    write_frame, Request, ServeStats, MAX_FRAME_BYTES,
};
use crate::chunk::WorkerPool;
use crate::coordinator::refactor::ProgressiveField;
use crate::error::{Error, Result};
use crate::obs::{self, Ctr, Gg, Hist};
use crate::progressive::ComponentId;
use crate::storage::ComponentCache;
use crate::{obs_info, obs_warn};
use crate::tensor::Scalar;
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How often a worker waiting on a socket re-checks the stop flag.
const STOP_POLL: Duration = Duration::from_millis(50);

/// Frame payloads are read in chunks of at most this many bytes, so a
/// hostile length prefix cannot force a large up-front allocation.
const READ_CHUNK: usize = 64 << 10;

/// Daemon configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address (`"127.0.0.1:0"` picks an ephemeral port; the bound
    /// address is available from [`Server::addr`]).
    pub addr: String,
    /// Shared component-cache capacity in bytes.
    pub cache_bytes: u64,
    /// Retry budget per component fetch on transient backend failures.
    pub retries: usize,
    /// Connections serviced concurrently (worker threads). Minimum 1.
    pub max_connections: usize,
    /// Admitted connections that may wait for a worker beyond the ones in
    /// service; anything past that is refused with a `Busy` frame
    /// (`queue_depth = 0` still admits while a worker is idle).
    pub queue_depth: usize,
    /// Per-request deadline in milliseconds, measured from the arrival of
    /// the request frame; `0` disables deadlines.
    pub request_timeout_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            cache_bytes: 64 << 20,
            retries: 3,
            max_connections: 16,
            queue_depth: 64,
            request_timeout_ms: 30_000,
        }
    }
}

struct Shared {
    field: ProgressiveField,
    cache: ComponentCache,
    timeout: Option<Duration>,
    requests: AtomicU64,
    connections: AtomicU64,
    queued: AtomicU64,
    refused: AtomicU64,
    deadline_expired: AtomicU64,
    stop: AtomicBool,
}

impl Shared {
    /// One component through the shared cache (single-flight backend
    /// fetch on a miss, with the field's retry budget bounded by the
    /// request deadline). Cache keys name the component's *physical*
    /// bytes — blob offsets, or `(shard object, inner range)` for
    /// sharded fields — so single-flight semantics hold per stored
    /// range regardless of layout.
    fn fetch_cached(&self, id: ComponentId, deadline: Option<Instant>) -> Result<Arc<Vec<u8>>> {
        let key = self.field.cache_key(id)?;
        self.cache
            .get_or_fetch(&key, || self.field.fetch_component_until(id, deadline))
    }

    fn stats(&self) -> ServeStats {
        let c = self.cache.stats();
        ServeStats {
            hits: c.hits,
            misses: c.misses,
            evictions: c.evictions,
            bytes_used: c.bytes_used,
            entries: c.entries,
            capacity: c.capacity,
            requests: self.requests.load(Ordering::Relaxed),
            connections: self.connections.load(Ordering::Relaxed),
            transient_retries: self.field.retries_spent(),
            queued: self.queued.load(Ordering::SeqCst),
            refused: self.refused.load(Ordering::Relaxed),
            coalesced: c.coalesced,
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
        }
    }
}

/// A running serve daemon. Dropping the server stops it.
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind `cfg.addr` and start serving `field`.
    pub fn start(mut field: ProgressiveField, cfg: &ServeConfig) -> Result<Server> {
        field.set_retry_budget(cfg.retries);
        let listener = TcpListener::bind(cfg.addr.as_str())?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            field,
            cache: ComponentCache::new(cfg.cache_bytes),
            timeout: (cfg.request_timeout_ms > 0)
                .then(|| Duration::from_millis(cfg.request_timeout_ms)),
            requests: AtomicU64::new(0),
            connections: AtomicU64::new(0),
            queued: AtomicU64::new(0),
            refused: AtomicU64::new(0),
            deadline_expired: AtomicU64::new(0),
            stop: AtomicBool::new(false),
        });
        obs_info!(
            "serve",
            "event=listening addr={addr} max_connections={} queue_depth={} timeout_ms={}",
            cfg.max_connections.max(1),
            cfg.queue_depth,
            cfg.request_timeout_ms
        );
        let accept_shared = Arc::clone(&shared);
        let (max_connections, queue_depth) = (cfg.max_connections.max(1), cfg.queue_depth);
        let accept = std::thread::spawn(move || {
            let pool_shared = Arc::clone(&accept_shared);
            let mut pool = WorkerPool::new(max_connections, queue_depth, move |stream: TcpStream| {
                let q = pool_shared.queued.fetch_sub(1, Ordering::SeqCst) - 1;
                obs::set_gauge(Gg::ServeQueued, q);
                handle_connection(&pool_shared, addr, stream);
            });
            for conn in listener.incoming() {
                if accept_shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                // count the admission *before* submitting so the gauge
                // never underflows when the worker decrements first
                let q = accept_shared.queued.fetch_add(1, Ordering::SeqCst) + 1;
                obs::set_gauge(Gg::ServeQueued, q);
                match pool.try_submit(stream) {
                    Ok(()) => {
                        accept_shared.connections.fetch_add(1, Ordering::Relaxed);
                        obs::inc(Ctr::ServeConnections);
                    }
                    Err(mut stream) => {
                        let q = accept_shared.queued.fetch_sub(1, Ordering::SeqCst) - 1;
                        obs::set_gauge(Gg::ServeQueued, q);
                        accept_shared.refused.fetch_add(1, Ordering::Relaxed);
                        obs::inc(Ctr::ServeRefused);
                        obs_warn!("serve", "event=refused reason=queue_full");
                        // refuse with a structured frame, never a hang or
                        // reset; a dead peer must not stall the accept loop
                        let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
                        let _ = write_frame(
                            &mut stream,
                            &busy_response("accept queue full, retry later"),
                        );
                        // closing with the peer's request still unread
                        // would RST the busy frame out of its receive
                        // buffer — drain (bounded) until the peer closes
                        let _ = stream.shutdown(std::net::Shutdown::Write);
                        let _ = stream.set_read_timeout(Some(STOP_POLL));
                        let drain_until = Instant::now() + Duration::from_millis(250);
                        let mut sink = [0u8; 1024];
                        while Instant::now() < drain_until {
                            match stream.read(&mut sink) {
                                Ok(0) => break,
                                Ok(_) => continue,
                                Err(e) if polls(&e) => continue,
                                Err(_) => break,
                            }
                        }
                    }
                }
            }
            // drains admitted connections, then joins the workers (they
            // observe the stop flag while polling their sockets)
            pool.shutdown();
            obs_info!("serve", "event=stopped addr={addr}");
        });
        Ok(Server {
            addr,
            shared,
            accept: Some(accept),
        })
    }

    /// The bound address (resolves an ephemeral-port bind).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Current daemon counters.
    pub fn stats(&self) -> ServeStats {
        self.shared.stats()
    }

    /// Block until the accept loop exits — i.e. until a client sends the
    /// protocol `shutdown` op or another thread flips the stop flag. This
    /// is what `mgardp serve` parks on after printing the bound address.
    pub fn wait(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting connections and join the accept loop. In-flight
    /// connections finish their current frame; idempotent.
    pub fn stop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // wake the accept loop so it observes the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

enum Outcome {
    Body(Vec<u8>),
    Shutdown,
}

/// [`super::protocol::read_frame`] for a worker: waits with a short read
/// timeout so the stop flag is observed within [`STOP_POLL`] even while a
/// client sits idle, reads payloads in [`READ_CHUNK`]-byte steps (a
/// hostile length prefix never forces a large up-front allocation), and
/// bounds *mid-frame* stalls by the request timeout so a slow-loris
/// client cannot hold a worker forever. Returns `Ok(None)` to drop the
/// connection (clean close or shutdown), `Err` on anything that cannot
/// be answered reliably.
fn read_frame_cancellable(stream: &mut TcpStream, shared: &Shared) -> Result<Option<Vec<u8>>> {
    stream.set_read_timeout(Some(STOP_POLL))?;
    let mut frame_start: Option<Instant> = None;
    let check_stall = |frame_start: &Option<Instant>| -> Result<()> {
        if shared.stop.load(Ordering::SeqCst) {
            return Err(Error::corrupt("daemon stopping"));
        }
        if let (Some(t0), Some(timeout)) = (frame_start, shared.timeout) {
            if t0.elapsed() > timeout {
                return Err(Error::corrupt("peer stalled mid-frame"));
            }
        }
        Ok(())
    };
    let mut len = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match stream.read(&mut len[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => return Err(Error::corrupt("connection closed mid-frame")),
            Ok(n) => {
                got += n;
                frame_start.get_or_insert_with(Instant::now);
            }
            Err(e) if polls(&e) => {
                if check_stall(&frame_start).is_err() {
                    return Ok(None);
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    let total = u32::from_le_bytes(len);
    if total > MAX_FRAME_BYTES {
        return Err(Error::corrupt(format!(
            "frame declares {total} bytes (cap {MAX_FRAME_BYTES})"
        )));
    }
    let total = total as usize;
    let mut payload = Vec::with_capacity(total.min(READ_CHUNK));
    let mut buf = vec![0u8; READ_CHUNK.min(total.max(1))];
    while payload.len() < total {
        let want = (total - payload.len()).min(buf.len());
        match stream.read(&mut buf[..want]) {
            Ok(0) => return Err(Error::corrupt("connection closed mid-frame")),
            Ok(n) => payload.extend_from_slice(&buf[..n]),
            Err(e) if polls(&e) => check_stall(&frame_start)?,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Some(payload))
}

/// Whether a socket error is the poll timeout (keep waiting) rather than
/// a real failure.
fn polls(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

fn handle_connection(shared: &Arc<Shared>, addr: SocketAddr, mut stream: TcpStream) {
    // per-connection fetch state: components already served, per stream
    let mut floor = vec![0usize; shared.field.manifest().streams.len()];
    loop {
        let payload = match read_frame_cancellable(&mut stream, shared) {
            Ok(Some(p)) => p,
            // clean close, shutdown, or a failure we can't answer
            Ok(None) | Err(_) => return,
        };
        shared.requests.fetch_add(1, Ordering::Relaxed);
        obs::inc(Ctr::ServeRequests);
        // the request span covers decode + handle + respond, the same
        // window the deadline measures (from frame arrival)
        let request_span = obs::span::enter(Hist::ServeRequest);
        let deadline = shared.timeout.map(|t| Instant::now() + t);
        let decoded = {
            let _s = obs::span::enter(Hist::ServeDecode);
            Request::decode_versioned(&payload)
        };
        let outcome = decoded.and_then(|(version, req)| {
            let _s = obs::span::enter(Hist::ServeHandle);
            handle_request(shared, &mut floor, version, req, deadline)
        });
        let (resp, stop_after) = match outcome {
            Ok(Outcome::Body(body)) => (ok_response(&body), false),
            Ok(Outcome::Shutdown) => (ok_response(&[]), true),
            Err(e) if e.is_deadline() => {
                shared.deadline_expired.fetch_add(1, Ordering::Relaxed);
                obs::inc(Ctr::ServeDeadlineExpired);
                obs_warn!("serve", "event=deadline_expired detail={e}");
                (deadline_response(&e.to_string()), false)
            }
            Err(e) => (err_response(&e.to_string()), false),
        };
        let wrote = {
            let _s = obs::span::enter(Hist::ServeRespond);
            write_frame(&mut stream, &resp)
        };
        drop(request_span);
        if wrote.is_err() {
            return;
        }
        if stop_after {
            shared.stop.store(true, Ordering::SeqCst);
            // wake the accept loop so it observes the flag
            let _ = TcpStream::connect(addr);
            return;
        }
    }
}

fn handle_request(
    shared: &Shared,
    floor: &mut [usize],
    version: u8,
    req: Request,
    deadline: Option<Instant>,
) -> Result<Outcome> {
    match req {
        Request::Manifest => Ok(Outcome::Body(shared.field.manifest().to_bytes())),
        Request::Plan { tau, floor: explicit } => {
            let base = match &explicit {
                Some(f) => f.as_slice(),
                None => floor,
            };
            let plan = shared.field.plan(tau, Some(base))?;
            Ok(Outcome::Body(encode_plan(&plan)))
        }
        Request::Fetch { stream, comp } => {
            let id = ComponentId { stream, comp };
            let bytes = shared.fetch_cached(id, deadline)?;
            // advance the connection floor only on in-order fetches, so it
            // always describes a contiguous prefix (a valid planner floor)
            if stream < floor.len() && comp == floor[stream] {
                floor[stream] += 1;
            }
            Ok(Outcome::Body(bytes.to_vec()))
        }
        Request::Retrieve { tau, region } => {
            let body = match shared.field.manifest().dtype {
                1 => retrieve_body::<f32>(shared, tau, region.as_deref(), deadline),
                2 => retrieve_body::<f64>(shared, tau, region.as_deref(), deadline),
                t => Err(Error::corrupt(format!("unknown dtype tag {t}"))),
            }?;
            Ok(Outcome::Body(body))
        }
        // stats bodies are shaped to the client's protocol version
        Request::Stats => Ok(Outcome::Body(shared.stats().encode_for(version))),
        // the text exposition of the whole process-wide registry; the op
        // itself is version-windowed at decode (v3+), so no shaping here
        Request::Metrics => Ok(Outcome::Body(
            crate::obs::registry::snapshot().render().into_bytes(),
        )),
        Request::Shutdown => Ok(Outcome::Shutdown),
    }
}

/// Server-side retrieval: plan for `tau`, pull the planned components
/// through the shared cache, reconstruct, optionally crop. Body layout:
/// `certified_bound: f64`, `rank: u64`, `rank × u64` shape, then the raw
/// little-endian scalars. The deadline is re-checked between component
/// fetches, so an expired request stops fetching promptly.
fn retrieve_body<T: Scalar>(
    shared: &Shared,
    tau: f64,
    region: Option<&[(usize, usize)]>,
    deadline: Option<Instant>,
) -> Result<Vec<u8>> {
    let plan = shared.field.plan(tau, None)?;
    let mut reader = shared.field.reader::<T>()?;
    for id in plan.components() {
        if let Some(d) = deadline {
            if Instant::now() >= d {
                return Err(Error::deadline("retrieve ran out of time mid-fetch"));
            }
        }
        reader.apply(id, &shared.fetch_cached(id, deadline)?)?;
    }
    let full = reader.reconstruct()?;
    let out = match region {
        Some(reg) => {
            if reg.len() != full.shape().len() {
                return Err(Error::invalid(format!(
                    "region rank {} for a rank-{} field",
                    reg.len(),
                    full.shape().len()
                )));
            }
            let start: Vec<usize> = reg.iter().map(|&(s, _)| s).collect();
            let size: Vec<usize> = reg.iter().map(|&(_, e)| e).collect();
            full.block(&start, &size)?
        }
        None => full,
    };
    let mut body = Vec::new();
    put_f64(&mut body, plan.certified_bound);
    put_u64(&mut body, out.shape().len() as u64);
    for &d in out.shape() {
        put_u64(&mut body, d as u64);
    }
    body.extend_from_slice(&out.to_le_bytes());
    Ok(body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::refactor::RefactorStore;
    use crate::metrics::linf_error;
    use crate::serve::client::{RemoteField, ServeClient};
    use crate::storage::{MemoryStorage, MockStorage, Storage};
    use std::time::Duration;

    fn memory_field(shape: &[usize]) -> (ProgressiveField, crate::tensor::Tensor<f32>) {
        let t = crate::data::synth::smooth_test_field(shape);
        let store = RefactorStore::with_storage(Arc::new(MemoryStorage::new()));
        store.write_field_progressive("u", &t, None, 3).unwrap();
        (store.progressive("u").unwrap(), t)
    }

    #[test]
    fn serves_plan_fetch_retrieve_and_stats() {
        let (field, t) = memory_field(&[17, 18]);
        let mut server = Server::start(field, &ServeConfig::default()).unwrap();
        let addr = server.addr();
        // client-side reconstruction via plan + fetch
        let mut remote: RemoteField<f32> = RemoteField::open(addr).unwrap();
        let (back, plan) = remote.refine(0.05).unwrap();
        assert!(plan.certified_bound <= 0.05);
        assert!(linf_error(t.data(), back.data()) <= 0.05);
        // tightening reuses the connection floor: only the delta transfers
        let (tight, plan2) = remote.refine(1e-3).unwrap();
        assert!(plan2.bytes >= plan.bytes);
        assert!(linf_error(t.data(), tight.data()) <= 1e-3);
        // server-side retrieval, whole field and a cropped region
        let mut client = ServeClient::connect(addr).unwrap();
        let (full, bound) = client.retrieve::<f32>(0.05, None).unwrap();
        assert!(bound <= 0.05);
        assert_eq!(full.shape(), t.shape());
        assert!(linf_error(t.data(), full.data()) <= 0.05);
        let (block, _) = client.retrieve::<f32>(0.05, Some(&[(2, 8), (3, 9)])).unwrap();
        assert_eq!(block.shape(), &[8, 9]);
        let direct = t.block(&[2, 3], &[8, 9]).unwrap();
        for (a, b) in direct.data().iter().zip(block.data()) {
            assert!((a - b).abs() as f64 <= 0.05);
        }
        // the second retrieval hit the shared cache
        let stats = client.stats().unwrap();
        assert!(stats.hits > 0, "{stats:?}");
        assert!(stats.connections >= 2);
        // live metrics exposition over the wire (v3 op): after at least
        // one request with telemetry on, the request histogram has
        // samples (the lock serializes against tests toggling the flag)
        {
            let _guard = crate::obs::test_lock();
            let was = obs::enabled();
            obs::set_enabled(true);
            client.stats().unwrap();
            let text = client.metrics().unwrap();
            obs::set_enabled(was);
            let line = text
                .lines()
                .find(|l| l.starts_with("hist serve.request "))
                .unwrap_or_else(|| panic!("no serve.request line in {text}"));
            let count: u64 = line.split_whitespace().nth(2).unwrap().parse().unwrap();
            assert!(count >= 1, "{line}");
            assert!(text.contains("counter serve.requests "), "{text}");
        }
        server.stop();
    }

    #[test]
    fn protocol_shutdown_stops_the_daemon() {
        let (field, _) = memory_field(&[9, 9]);
        let mut server = Server::start(field, &ServeConfig::default()).unwrap();
        let mut client = ServeClient::connect(server.addr()).unwrap();
        client.shutdown().unwrap();
        // the accept loop has exited (or is about to); joining must not hang
        server.stop();
    }

    #[test]
    fn survives_mock_latency_and_transient_failures() {
        let t = crate::data::synth::smooth_test_field(&[17, 17]);
        let mem = Arc::new(MemoryStorage::new());
        let writer = RefactorStore::with_storage(Arc::clone(&mem) as Arc<dyn Storage>);
        writer.write_field_progressive("u", &t, None, 3).unwrap();
        let mock = Arc::new(MockStorage::new(
            mem,
            Duration::from_micros(200),
            5, // every 5th read fails transiently
        ));
        let store = RefactorStore::with_storage(mock);
        let field = store.progressive("u").unwrap();
        let cfg = ServeConfig {
            retries: 4,
            ..ServeConfig::default()
        };
        let mut server = Server::start(field, &cfg).unwrap();
        let mut remote: RemoteField<f32> = RemoteField::open(server.addr()).unwrap();
        let (back, plan) = remote.refine(0.01).unwrap();
        assert!(plan.certified_bound <= 0.01);
        assert!(linf_error(t.data(), back.data()) <= 0.01);
        let stats = server.stats();
        assert!(stats.transient_retries > 0, "{stats:?}");
        server.stop();
    }

    #[test]
    fn overload_refuses_with_a_structured_busy_frame() {
        use super::super::protocol::{parse_response, read_frame};
        let (field, _) = memory_field(&[9, 9]);
        let cfg = ServeConfig {
            max_connections: 1,
            queue_depth: 0,
            ..ServeConfig::default()
        };
        let mut server = Server::start(field, &cfg).unwrap();
        let addr = server.addr();
        // occupy the single worker and prove it is in service
        let mut holder = ServeClient::connect(addr).unwrap();
        holder.stats().unwrap();
        // the next connection must be refused with a Busy frame — read it
        // without writing anything (the frame is sent at accept time)
        let mut refused = std::net::TcpStream::connect(addr).unwrap();
        refused
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let frame = read_frame(&mut refused).unwrap().expect("a busy frame, not a close");
        match parse_response(&frame) {
            Err(Error::Busy(msg)) => assert!(msg.contains("queue full"), "{msg}"),
            other => panic!("expected Busy, got {other:?}"),
        }
        drop(refused);
        // a full client sees the refusal as Error::Busy too
        let mut client = ServeClient::connect(addr).unwrap();
        match client.stats() {
            Err(Error::Busy(_)) => {}
            other => panic!("expected Busy, got {other:?}"),
        }
        // the admitted connection is unaffected and the counter advanced
        let stats = holder.stats().unwrap();
        assert!(stats.refused >= 2, "{stats:?}");
        assert_eq!(stats.connections, 1, "{stats:?}");
        drop(holder);
        server.stop();
    }

    #[test]
    fn queued_connections_are_served_once_a_worker_frees() {
        let (field, t) = memory_field(&[9, 9]);
        let cfg = ServeConfig {
            max_connections: 1,
            queue_depth: 4,
            ..ServeConfig::default()
        };
        let mut server = Server::start(field, &cfg).unwrap();
        let addr = server.addr();
        let holder = ServeClient::connect(addr).unwrap();
        // second connection is admitted into the queue, parks until the
        // holder disconnects, then gets the worker and full service
        let waiter = std::thread::spawn(move || {
            let mut client = ServeClient::connect(addr).unwrap();
            let (back, bound) = client.retrieve::<f32>(0.05, None).unwrap();
            (back, bound)
        });
        std::thread::sleep(Duration::from_millis(100));
        drop(holder); // frees the worker
        let (back, bound) = waiter.join().unwrap();
        assert!(bound <= 0.05);
        assert!(linf_error(t.data(), back.data()) <= 0.05);
        server.stop();
    }

    #[test]
    fn expired_deadlines_answer_with_a_deadline_frame() {
        let t = crate::data::synth::smooth_test_field(&[17, 17]);
        let mem = Arc::new(MemoryStorage::new());
        let writer = RefactorStore::with_storage(Arc::clone(&mem) as Arc<dyn Storage>);
        writer.write_field_progressive("u", &t, None, 3).unwrap();
        // slow enough that a ~1ms budget dies between component fetches
        let mock = Arc::new(MockStorage::new(mem, Duration::from_millis(20), 0));
        let store = RefactorStore::with_storage(mock);
        let field = store.progressive("u").unwrap();
        let cfg = ServeConfig {
            request_timeout_ms: 1,
            ..ServeConfig::default()
        };
        let mut server = Server::start(field, &cfg).unwrap();
        let mut client = ServeClient::connect(server.addr()).unwrap();
        match client.retrieve::<f32>(1e-3, None) {
            Err(Error::Deadline(_)) => {}
            other => panic!("expected Deadline, got {other:?}"),
        }
        // the connection stays usable: manifest needs no backend reads
        client.manifest().unwrap();
        let stats = client.stats().unwrap();
        assert!(stats.deadline_expired >= 1, "{stats:?}");
        server.stop();
    }

    #[test]
    fn version_1_clients_get_version_1_stats_bodies() {
        use super::super::protocol::{parse_response, read_frame, SERVE_PROTOCOL_VERSION};
        let (field, _) = memory_field(&[9, 9]);
        let mut server = Server::start(field, &ServeConfig::default()).unwrap();
        let mut raw = std::net::TcpStream::connect(server.addr()).unwrap();
        raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        // a stats request with the version byte rewritten to 1
        let mut req = Request::Stats.encode();
        assert_eq!(req[4], SERVE_PROTOCOL_VERSION);
        req[4] = 1;
        write_frame(&mut raw, &req).unwrap();
        let resp = read_frame(&mut raw).unwrap().unwrap();
        let body = parse_response(&resp).unwrap();
        assert_eq!(body.len(), 9 * 8, "v1 stats body is nine u64s");
        // the same connection answers a current-version request in full
        write_frame(&mut raw, &Request::Stats.encode()).unwrap();
        let resp = read_frame(&mut raw).unwrap().unwrap();
        let body = parse_response(&resp).unwrap();
        assert_eq!(body.len(), 13 * 8, "v2 stats body is thirteen u64s");
        drop(raw);
        server.stop();
    }

    #[test]
    fn stop_drains_workers_with_idle_connections_open() {
        let (field, _) = memory_field(&[9, 9]);
        let mut server = Server::start(field, &ServeConfig::default()).unwrap();
        // open connections and leave them idle — no frames at all
        let idle: Vec<_> = (0..4)
            .map(|_| ServeClient::connect(server.addr()).unwrap())
            .collect();
        std::thread::sleep(Duration::from_millis(60));
        // stop() joins the accept thread, which drains the worker pool;
        // returning at all proves no worker is wedged on an idle socket
        server.stop();
        drop(idle);
    }
}
