//! The `mgardp serve` daemon: concurrent error-bounded retrieval.
//!
//! One [`Server`] owns one progressively refactored field (over any
//! [`crate::storage::Storage`] backend) and answers simultaneous clients
//! over plain TCP — a hand-rolled thread-per-connection loop on
//! [`std::net::TcpListener`], no external crates. All connections share
//! one byte-capacity [`ComponentCache`], so the hot prefix components
//! (sign planes, high bitplanes) are fetched from the backend once and
//! then served from memory to every client; per-connection **fetch
//! state** (components already served on that connection) lets a `plan`
//! request with no explicit floor return exactly the delta the client
//! still needs.
//!
//! Shutdown is cooperative: the `shutdown` op (or [`Server::stop`]) sets
//! a flag and wakes the accept loop with a loopback connection, so the
//! daemon exits without killing in-flight connections mid-frame.

use super::protocol::{
    encode_plan, err_response, ok_response, put_f64, put_u64, read_frame, write_frame, Request,
    ServeStats,
};
use crate::coordinator::refactor::ProgressiveField;
use crate::error::{Error, Result};
use crate::progressive::ComponentId;
use crate::storage::ComponentCache;
use crate::tensor::Scalar;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Daemon configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address (`"127.0.0.1:0"` picks an ephemeral port; the bound
    /// address is available from [`Server::addr`]).
    pub addr: String,
    /// Shared component-cache capacity in bytes.
    pub cache_bytes: u64,
    /// Retry budget per component fetch on transient backend failures.
    pub retries: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            cache_bytes: 64 << 20,
            retries: 3,
        }
    }
}

struct Shared {
    field: ProgressiveField,
    cache: ComponentCache,
    requests: AtomicU64,
    connections: AtomicU64,
    stop: AtomicBool,
}

impl Shared {
    /// One component through the shared cache (backend fetch on a miss,
    /// with the field's retry budget).
    fn fetch_cached(&self, id: ComponentId) -> Result<Arc<Vec<u8>>> {
        let key = format!("{}/{}", id.stream, id.comp);
        self.cache
            .get_or_fetch(&key, || self.field.fetch_component(id))
    }

    fn stats(&self) -> ServeStats {
        let c = self.cache.stats();
        ServeStats {
            hits: c.hits,
            misses: c.misses,
            evictions: c.evictions,
            bytes_used: c.bytes_used,
            entries: c.entries,
            capacity: c.capacity,
            requests: self.requests.load(Ordering::Relaxed),
            connections: self.connections.load(Ordering::Relaxed),
            transient_retries: self.field.retries_spent(),
        }
    }
}

/// A running serve daemon. Dropping the server stops it.
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind `cfg.addr` and start serving `field`.
    pub fn start(mut field: ProgressiveField, cfg: &ServeConfig) -> Result<Server> {
        field.set_retry_budget(cfg.retries);
        let listener = TcpListener::bind(cfg.addr.as_str())?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            field,
            cache: ComponentCache::new(cfg.cache_bytes),
            requests: AtomicU64::new(0),
            connections: AtomicU64::new(0),
            stop: AtomicBool::new(false),
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if accept_shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                accept_shared.connections.fetch_add(1, Ordering::Relaxed);
                let conn_shared = Arc::clone(&accept_shared);
                std::thread::spawn(move || handle_connection(&conn_shared, addr, stream));
            }
        });
        Ok(Server {
            addr,
            shared,
            accept: Some(accept),
        })
    }

    /// The bound address (resolves an ephemeral-port bind).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Current daemon counters.
    pub fn stats(&self) -> ServeStats {
        self.shared.stats()
    }

    /// Block until the accept loop exits — i.e. until a client sends the
    /// protocol `shutdown` op or another thread flips the stop flag. This
    /// is what `mgardp serve` parks on after printing the bound address.
    pub fn wait(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting connections and join the accept loop. In-flight
    /// connections finish their current frame; idempotent.
    pub fn stop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // wake the accept loop so it observes the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

enum Outcome {
    Body(Vec<u8>),
    Shutdown,
}

fn handle_connection(shared: &Arc<Shared>, addr: SocketAddr, mut stream: TcpStream) {
    // per-connection fetch state: components already served, per stream
    let mut floor = vec![0usize; shared.field.manifest().streams.len()];
    loop {
        let payload = match read_frame(&mut stream) {
            Ok(Some(p)) => p,
            // clean close, or a connection-level failure we can't answer
            Ok(None) | Err(_) => return,
        };
        shared.requests.fetch_add(1, Ordering::Relaxed);
        let outcome = Request::decode(&payload).and_then(|req| handle_request(shared, &mut floor, req));
        let (resp, stop_after) = match outcome {
            Ok(Outcome::Body(body)) => (ok_response(&body), false),
            Ok(Outcome::Shutdown) => (ok_response(&[]), true),
            Err(e) => (err_response(&e.to_string()), false),
        };
        if write_frame(&mut stream, &resp).is_err() {
            return;
        }
        if stop_after {
            shared.stop.store(true, Ordering::SeqCst);
            // wake the accept loop so it observes the flag
            let _ = TcpStream::connect(addr);
            return;
        }
    }
}

fn handle_request(shared: &Shared, floor: &mut [usize], req: Request) -> Result<Outcome> {
    match req {
        Request::Manifest => Ok(Outcome::Body(shared.field.manifest().to_bytes())),
        Request::Plan { tau, floor: explicit } => {
            let base = match &explicit {
                Some(f) => f.as_slice(),
                None => floor,
            };
            let plan = shared.field.plan(tau, Some(base))?;
            Ok(Outcome::Body(encode_plan(&plan)))
        }
        Request::Fetch { stream, comp } => {
            let id = ComponentId { stream, comp };
            let bytes = shared.fetch_cached(id)?;
            // advance the connection floor only on in-order fetches, so it
            // always describes a contiguous prefix (a valid planner floor)
            if stream < floor.len() && comp == floor[stream] {
                floor[stream] += 1;
            }
            Ok(Outcome::Body(bytes.to_vec()))
        }
        Request::Retrieve { tau, region } => {
            let body = match shared.field.manifest().dtype {
                1 => retrieve_body::<f32>(shared, tau, region.as_deref()),
                2 => retrieve_body::<f64>(shared, tau, region.as_deref()),
                t => Err(Error::corrupt(format!("unknown dtype tag {t}"))),
            }?;
            Ok(Outcome::Body(body))
        }
        Request::Stats => Ok(Outcome::Body(shared.stats().encode())),
        Request::Shutdown => Ok(Outcome::Shutdown),
    }
}

/// Server-side retrieval: plan for `tau`, pull the planned components
/// through the shared cache, reconstruct, optionally crop. Body layout:
/// `certified_bound: f64`, `rank: u64`, `rank × u64` shape, then the raw
/// little-endian scalars.
fn retrieve_body<T: Scalar>(
    shared: &Shared,
    tau: f64,
    region: Option<&[(usize, usize)]>,
) -> Result<Vec<u8>> {
    let plan = shared.field.plan(tau, None)?;
    let mut reader = shared.field.reader::<T>()?;
    for id in plan.components() {
        reader.apply(id, &shared.fetch_cached(id)?)?;
    }
    let full = reader.reconstruct()?;
    let out = match region {
        Some(reg) => {
            if reg.len() != full.shape().len() {
                return Err(Error::invalid(format!(
                    "region rank {} for a rank-{} field",
                    reg.len(),
                    full.shape().len()
                )));
            }
            let start: Vec<usize> = reg.iter().map(|&(s, _)| s).collect();
            let size: Vec<usize> = reg.iter().map(|&(_, e)| e).collect();
            full.block(&start, &size)?
        }
        None => full,
    };
    let mut body = Vec::new();
    put_f64(&mut body, plan.certified_bound);
    put_u64(&mut body, out.shape().len() as u64);
    for &d in out.shape() {
        put_u64(&mut body, d as u64);
    }
    body.extend_from_slice(&out.to_le_bytes());
    Ok(body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::refactor::RefactorStore;
    use crate::metrics::linf_error;
    use crate::serve::client::{RemoteField, ServeClient};
    use crate::storage::{MemoryStorage, MockStorage, Storage};
    use std::time::Duration;

    fn memory_field(shape: &[usize]) -> (ProgressiveField, crate::tensor::Tensor<f32>) {
        let t = crate::data::synth::smooth_test_field(shape);
        let store = RefactorStore::with_storage(Arc::new(MemoryStorage::new()));
        store.write_field_progressive("u", &t, None, 3).unwrap();
        (store.progressive("u").unwrap(), t)
    }

    #[test]
    fn serves_plan_fetch_retrieve_and_stats() {
        let (field, t) = memory_field(&[17, 18]);
        let mut server = Server::start(field, &ServeConfig::default()).unwrap();
        let addr = server.addr();
        // client-side reconstruction via plan + fetch
        let mut remote: RemoteField<f32> = RemoteField::open(addr).unwrap();
        let (back, plan) = remote.refine(0.05).unwrap();
        assert!(plan.certified_bound <= 0.05);
        assert!(linf_error(t.data(), back.data()) <= 0.05);
        // tightening reuses the connection floor: only the delta transfers
        let (tight, plan2) = remote.refine(1e-3).unwrap();
        assert!(plan2.bytes >= plan.bytes);
        assert!(linf_error(t.data(), tight.data()) <= 1e-3);
        // server-side retrieval, whole field and a cropped region
        let mut client = ServeClient::connect(addr).unwrap();
        let (full, bound) = client.retrieve::<f32>(0.05, None).unwrap();
        assert!(bound <= 0.05);
        assert_eq!(full.shape(), t.shape());
        assert!(linf_error(t.data(), full.data()) <= 0.05);
        let (block, _) = client.retrieve::<f32>(0.05, Some(&[(2, 8), (3, 9)])).unwrap();
        assert_eq!(block.shape(), &[8, 9]);
        let direct = t.block(&[2, 3], &[8, 9]).unwrap();
        for (a, b) in direct.data().iter().zip(block.data()) {
            assert!((a - b).abs() as f64 <= 0.05);
        }
        // the second retrieval hit the shared cache
        let stats = client.stats().unwrap();
        assert!(stats.hits > 0, "{stats:?}");
        assert!(stats.connections >= 2);
        server.stop();
    }

    #[test]
    fn protocol_shutdown_stops_the_daemon() {
        let (field, _) = memory_field(&[9, 9]);
        let mut server = Server::start(field, &ServeConfig::default()).unwrap();
        let mut client = ServeClient::connect(server.addr()).unwrap();
        client.shutdown().unwrap();
        // the accept loop has exited (or is about to); joining must not hang
        server.stop();
    }

    #[test]
    fn survives_mock_latency_and_transient_failures() {
        let t = crate::data::synth::smooth_test_field(&[17, 17]);
        let mem = Arc::new(MemoryStorage::new());
        let writer = RefactorStore::with_storage(Arc::clone(&mem) as Arc<dyn Storage>);
        writer.write_field_progressive("u", &t, None, 3).unwrap();
        let mock = Arc::new(MockStorage::new(
            mem,
            Duration::from_micros(200),
            5, // every 5th read fails transiently
        ));
        let store = RefactorStore::with_storage(mock);
        let field = store.progressive("u").unwrap();
        let cfg = ServeConfig {
            retries: 4,
            ..ServeConfig::default()
        };
        let mut server = Server::start(field, &cfg).unwrap();
        let mut remote: RemoteField<f32> = RemoteField::open(server.addr()).unwrap();
        let (back, plan) = remote.refine(0.01).unwrap();
        assert!(plan.certified_bound <= 0.01);
        assert!(linf_error(t.data(), back.data()) <= 0.01);
        let stats = server.stats();
        assert!(stats.transient_retries > 0, "{stats:?}");
        server.stop();
    }
}
