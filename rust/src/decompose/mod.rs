//! Multilevel decomposition / recomposition (§2) with the MGARD+ performance
//! optimizations (§5).
//!
//! Two engines implement the same transform:
//!
//! * [`baseline`] — the original method as described in §2: operates in
//!   place on the full array with strided accesses whose stride doubles per
//!   level, computes load vectors by fine-grained mass-matrix multiplication
//!   followed by restriction, and re-derives the tridiagonal auxiliary
//!   arrays for every line. This is the reference point for the Fig. 6
//!   speedups.
//! * [`contiguous`] — the MGARD+ engine: level-centric data reordering (DR,
//!   §5.1), direct load-vector computation (DLVC, §5.2), batched correction
//!   computation (BCC, §5.3), and intermediate-variable elimination & reuse
//!   (IVER, §5.4), each individually switchable for the ablation.
//!
//! Both produce a [`Decomposition`]: the coarse representation `Q_l̃ u` plus
//! per-level multilevel-coefficient streams in a canonical order (row-major
//! over the level grid, skipping nodes already present in the next coarser
//! grid), so their outputs are interchangeable bit-for-bit up to FP rounding.

pub mod baseline;
pub mod contiguous;
pub mod fused;
pub mod sweeps;

pub use contiguous::{DecomposeScratch, DEFAULT_PANEL_WIDTH};
pub use sweeps::LinePanel;

use crate::error::{Error, Result};
use crate::grid::Hierarchy;
use crate::tensor::{Scalar, Tensor};

/// Streaming consumer of the coefficient nodes a decomposition step emits.
///
/// `split_level` compacts each level's nodal values into the next coarse
/// array and hands every coefficient node to a `CoeffSink` instead of
/// materializing a per-level buffer — the seam that lets the level-wise
/// quantizer ([`crate::quant::QuantSink`]) consume coefficients *as they
/// are compacted* (the fused decompose→quantize hot path, [`fused`]).
///
/// # Invariants the producer guarantees
///
/// * Values arrive in the **canonical coefficient order** of the level
///   (row-major over the level grid, skipping nodes of the next coarser
///   grid) — exactly the order [`Decomposition::coeffs`] stores.
/// * One decomposition step emits exactly
///   [`Hierarchy::num_coeff_nodes`]`(l)` values, split into an arbitrary
///   mix of [`CoeffSink::run`] slices and single [`CoeffSink::push`] calls;
///   a sink must treat both identically.
/// * The producer never inspects sink state: any sink observing the same
///   value sequence produces the same result, so a `Vec<T>` sink (staged)
///   and a quantizing sink (fused) are interchangeable bit-for-bit.
pub trait CoeffSink<T: Scalar> {
    /// Consume one contiguous run of coefficient values.
    fn run(&mut self, values: &[T]);

    /// Consume a single coefficient value.
    fn push(&mut self, value: T);
}

/// The staged sink: collect the level's coefficient stream into a `Vec`.
impl<T: Scalar> CoeffSink<T> for Vec<T> {
    #[inline]
    fn run(&mut self, values: &[T]) {
        self.extend_from_slice(values);
    }

    #[inline]
    fn push(&mut self, value: T) {
        Vec::push(self, value);
    }
}

/// Which of the §5 optimizations are enabled (Fig. 6 ablation knobs), plus
/// the fused decompose→quantize hot path this reproduction adds on top.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OptFlags {
    /// DR: level-centric data reordering (§5.1). Off = baseline engine.
    pub reorder: bool,
    /// DLVC: direct load-vector computation (§5.2).
    pub direct_load: bool,
    /// BCC: batched correction computation (§5.3).
    pub batched: bool,
    /// IVER: intermediate-variable elimination & reuse (§5.4).
    pub reuse: bool,
    /// Fused decompose→quantize: `compressors::MgardPlus` streams each
    /// level's coefficients straight into the level-wise quantizer via
    /// [`CoeffSink`] instead of staging per-level buffers. Output bytes are
    /// bit-identical either way (the staged path is the differential
    /// oracle); this only changes speed and peak memory. Requires
    /// `reorder`; takes effect when the tier schedule is static (adaptive
    /// termination off — with it on, the schedule depends on the stop
    /// level, so the staged path runs).
    pub fused: bool,
}

impl OptFlags {
    /// The original multilevel method (no optimizations).
    pub fn baseline() -> Self {
        OptFlags {
            reorder: false,
            direct_load: false,
            batched: false,
            reuse: false,
            fused: false,
        }
    }

    /// +DR only.
    pub fn dr() -> Self {
        OptFlags {
            reorder: true,
            direct_load: false,
            batched: false,
            reuse: false,
            fused: false,
        }
    }

    /// +DR +DLVC.
    pub fn dr_dlvc() -> Self {
        OptFlags {
            reorder: true,
            direct_load: true,
            batched: false,
            reuse: false,
            fused: false,
        }
    }

    /// +DR +DLVC +BCC.
    pub fn dr_dlvc_bcc() -> Self {
        OptFlags {
            reorder: true,
            direct_load: true,
            batched: true,
            reuse: false,
            fused: false,
        }
    }

    /// All optimizations (the MGARD+ configuration, fused hot path on).
    pub fn all() -> Self {
        OptFlags {
            fused: true,
            ..Self::all_staged()
        }
    }

    /// All §5 optimizations with the fused hot path off: the staged
    /// differential oracle the fused path is byte-compared against.
    pub fn all_staged() -> Self {
        OptFlags {
            reorder: true,
            direct_load: true,
            batched: true,
            reuse: true,
            fused: false,
        }
    }

    /// The five cumulative configurations of Fig. 6, with display labels.
    pub fn fig6_series() -> Vec<(&'static str, OptFlags)> {
        vec![
            ("MGARD", OptFlags::baseline()),
            ("+DR", OptFlags::dr()),
            ("+DLVC", OptFlags::dr_dlvc()),
            ("+BCC", OptFlags::dr_dlvc_bcc()),
            ("+IVER", OptFlags::all()),
        ]
    }

    /// Check the cumulative-optimization dependencies (DLVC/BCC/IVER and
    /// the fused hot path require `reorder`; BCC requires DLVC). Public so
    /// config layers (coordinator CLI/pipeline) can reject inconsistent
    /// knob combinations with a structured error before construction.
    pub fn validate(&self) -> Result<()> {
        if !self.reorder && (self.direct_load || self.batched || self.reuse || self.fused) {
            return Err(Error::invalid(
                "the baseline (non-reordered) engine does not support DLVC/BCC/IVER or the \
                 fused hot path; enable `reorder` first (the paper applies the optimizations \
                 cumulatively)",
            ));
        }
        if self.batched && !self.direct_load {
            return Err(Error::invalid(
                "BCC requires DLVC (the batched sweep implements the direct stencil only)",
            ));
        }
        Ok(())
    }
}

/// Result of a (possibly adaptive/partial) multilevel decomposition.
///
/// `coarse` holds `Q_l̃ u` on grid `N_l̃` and `coeffs[k]` holds the level
/// `l̃+1+k` multilevel coefficients (values on `N_{l̃+1+k}^*`) in canonical
/// order. A full decomposition has `start_level == 0`.
#[derive(Clone, Debug)]
pub struct Decomposition<T: Scalar> {
    /// The grid hierarchy this decomposition lives on.
    pub hierarchy: Hierarchy,
    /// `l̃`: the level at which decomposition stopped (0 = complete).
    pub start_level: usize,
    /// `Q_l̃ u` — the coarse representation, shape `hierarchy.level_shape(l̃)`.
    pub coarse: Tensor<T>,
    /// Per-level coefficient streams for levels `l̃+1 ..= L`.
    pub coeffs: Vec<Vec<T>>,
}

impl<T: Scalar> Decomposition<T> {
    /// The finest level `L`.
    pub fn max_level(&self) -> usize {
        self.hierarchy.nlevels()
    }

    /// Absolute level of `coeffs[k]`.
    pub fn coeff_level(&self, k: usize) -> usize {
        self.start_level + 1 + k
    }

    /// Consistency check: stream lengths must match `#N_l^*` of each level.
    pub fn validate(&self) -> Result<()> {
        let h = &self.hierarchy;
        if self.coarse.shape() != h.level_shape(self.start_level).as_slice() {
            return Err(Error::shape("decomposition coarse shape mismatch"));
        }
        if self.start_level + self.coeffs.len() != h.nlevels() {
            return Err(Error::shape(format!(
                "decomposition has {} coefficient levels; expected {}",
                self.coeffs.len(),
                h.nlevels() - self.start_level
            )));
        }
        for (k, c) in self.coeffs.iter().enumerate() {
            let l = self.coeff_level(k);
            if c.len() != h.num_coeff_nodes(l) {
                return Err(Error::shape(format!(
                    "level {l} stream has {} values; expected {}",
                    c.len(),
                    h.num_coeff_nodes(l)
                )));
            }
        }
        Ok(())
    }
}

/// Multilevel decomposer: a [`Hierarchy`] plus an [`OptFlags`] configuration.
#[derive(Clone, Debug)]
pub struct Decomposer {
    hierarchy: Hierarchy,
    flags: OptFlags,
}

impl Decomposer {
    /// Create a decomposer; validates the flag combination.
    pub fn new(hierarchy: Hierarchy, flags: OptFlags) -> Result<Self> {
        flags.validate()?;
        Ok(Decomposer { hierarchy, flags })
    }

    /// The hierarchy this decomposer operates on.
    pub fn hierarchy(&self) -> &Hierarchy {
        &self.hierarchy
    }

    /// The optimization configuration.
    pub fn flags(&self) -> OptFlags {
        self.flags
    }

    /// Fully decompose `u` (original shape; padding applied internally).
    pub fn decompose<T: Scalar>(&self, u: &Tensor<T>) -> Result<Decomposition<T>> {
        self.decompose_to(u, 0)
    }

    /// Like [`Decomposer::decompose`], but reusing `scratch` for every
    /// internal buffer (sweeps, corrections, compactions). Bit-identical to
    /// the fresh-scratch path; the baseline (non-reordered) engine ignores
    /// the scratch.
    pub fn decompose_scratch<T: Scalar>(
        &self,
        u: &Tensor<T>,
        scratch: &mut DecomposeScratch<T>,
    ) -> Result<Decomposition<T>> {
        let padded = self.hierarchy.pad(u)?;
        let d = if self.flags.reorder {
            contiguous::decompose_scratch(&self.hierarchy, self.flags, padded, 0, scratch)
        } else {
            baseline::decompose(&self.hierarchy, padded, 0)
        };
        debug_assert!(d.validate().is_ok());
        Ok(d)
    }

    /// Like [`Decomposer::recompose`], but reusing `scratch` for every
    /// internal buffer. Bit-identical to the fresh-scratch path; the
    /// baseline engine ignores the scratch.
    pub fn recompose_scratch<T: Scalar>(
        &self,
        d: &Decomposition<T>,
        scratch: &mut DecomposeScratch<T>,
    ) -> Result<Tensor<T>> {
        d.validate()?;
        let full = if self.flags.reorder {
            contiguous::recompose_scratch(
                &self.hierarchy,
                self.flags,
                d,
                self.hierarchy.nlevels(),
                scratch,
            )?
        } else {
            baseline::recompose(&self.hierarchy, d, self.hierarchy.nlevels())?
        };
        self.hierarchy.crop(&full)
    }

    /// Decompose down to `stop_level` (inclusive); `stop_level == L` is a
    /// no-op decomposition whose "coarse" representation is the input.
    pub fn decompose_to<T: Scalar>(
        &self,
        u: &Tensor<T>,
        stop_level: usize,
    ) -> Result<Decomposition<T>> {
        if stop_level > self.hierarchy.nlevels() {
            return Err(Error::invalid(format!(
                "stop_level {stop_level} > L = {}",
                self.hierarchy.nlevels()
            )));
        }
        let padded = self.hierarchy.pad(u)?;
        let d = if self.flags.reorder {
            contiguous::decompose(&self.hierarchy, self.flags, padded, stop_level)
        } else {
            baseline::decompose(&self.hierarchy, padded, stop_level)
        };
        debug_assert!(d.validate().is_ok());
        Ok(d)
    }

    /// Full recomposition back to the original shape.
    pub fn recompose<T: Scalar>(&self, d: &Decomposition<T>) -> Result<Tensor<T>> {
        d.validate()?;
        let full = if self.flags.reorder {
            contiguous::recompose(&self.hierarchy, self.flags, d, self.hierarchy.nlevels())?
        } else {
            baseline::recompose(&self.hierarchy, d, self.hierarchy.nlevels())?
        };
        self.hierarchy.crop(&full)
    }

    /// Partial recomposition: returns `Q_l u` on grid `N_l` (the reduced
    /// representation used for refactoring and coarse-grained analysis,
    /// §6.2.2). Values live on the padded domain's level grid.
    pub fn recompose_to_level<T: Scalar>(
        &self,
        d: &Decomposition<T>,
        level: usize,
    ) -> Result<Tensor<T>> {
        // partial validation: only the streams up to `level` are needed, so
        // a progressively-retrieved decomposition (refactor store) may omit
        // the finer ones
        if d.coarse.shape() != self.hierarchy.level_shape(d.start_level).as_slice() {
            return Err(Error::shape("decomposition coarse shape mismatch"));
        }
        if d.start_level + d.coeffs.len() < level {
            return Err(Error::invalid(format!(
                "recompose to level {level} needs {} streams, decomposition has {}",
                level - d.start_level,
                d.coeffs.len()
            )));
        }
        for k in 0..(level - d.start_level) {
            let l = d.coeff_level(k);
            if d.coeffs[k].len() != self.hierarchy.num_coeff_nodes(l) {
                return Err(Error::shape(format!("level {l} stream length mismatch")));
            }
        }
        if level < d.start_level || level > self.hierarchy.nlevels() {
            return Err(Error::invalid(format!(
                "recompose level {level} outside [{}, {}]",
                d.start_level,
                self.hierarchy.nlevels()
            )));
        }
        if self.flags.reorder {
            contiguous::recompose(&self.hierarchy, self.flags, d, level)
        } else {
            baseline::recompose(&self.hierarchy, d, level)
        }
    }
}

/// Iterate the canonical coefficient-node order of level `l`: row-major over
/// `N_l`'s level grid, skipping nodes present in `N_{l-1}`. Calls `f` with
/// the node's level-grid multi-index.
///
/// A node belongs to `N_{l-1}` iff its coordinate is even along every dim
/// that is active (still halving) at step `l`.
pub(crate) fn for_each_coeff_node(
    hierarchy: &Hierarchy,
    l: usize,
    mut f: impl FnMut(&[usize]),
) {
    let shape = hierarchy.level_shape(l);
    let active: Vec<bool> = (0..shape.len())
        .map(|d| l >= 1 && hierarchy.dim_active(l, d))
        .collect();
    crate::tensor::for_each_index(&shape, |ix| {
        let nodal = ix
            .iter()
            .enumerate()
            .all(|(d, &i)| !active[d] || i % 2 == 0);
        if !nodal {
            f(ix);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_combos_validated() {
        assert!(Decomposer::new(Hierarchy::new(&[9, 9], None).unwrap(), OptFlags::all()).is_ok());
        let bad = OptFlags {
            reorder: false,
            direct_load: true,
            batched: false,
            reuse: false,
            fused: false,
        };
        assert!(Decomposer::new(Hierarchy::new(&[9, 9], None).unwrap(), bad).is_err());
        let bad2 = OptFlags {
            reorder: true,
            direct_load: false,
            batched: true,
            reuse: false,
            fused: false,
        };
        assert!(Decomposer::new(Hierarchy::new(&[9, 9], None).unwrap(), bad2).is_err());
        let bad3 = OptFlags {
            reorder: false,
            direct_load: false,
            batched: false,
            reuse: false,
            fused: true,
        };
        assert!(Decomposer::new(Hierarchy::new(&[9, 9], None).unwrap(), bad3).is_err());
    }

    #[test]
    fn coeff_node_count_matches_hierarchy() {
        let h = Hierarchy::new(&[9, 17], None).unwrap();
        for l in 1..=h.nlevels() {
            let mut count = 0;
            for_each_coeff_node(&h, l, |_| count += 1);
            assert_eq!(count, h.num_coeff_nodes(l), "level {l}");
        }
    }

    #[test]
    fn fig6_series_is_cumulative() {
        let series = OptFlags::fig6_series();
        assert_eq!(series.len(), 5);
        assert_eq!(series[0].1, OptFlags::baseline());
        assert_eq!(series[4].1, OptFlags::all());
        for (_, f) in &series {
            assert!(f.validate().is_ok());
        }
    }
}
