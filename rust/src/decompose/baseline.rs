//! The original multilevel method (§2), deliberately un-optimized.
//!
//! This engine is the reference point for every Fig. 6 speedup bar: it
//! operates *in place* on the full padded array with strided node access
//! whose stride doubles at each level (the cache-hostile pattern of Fig. 1),
//! computes load vectors by fine-grained mass-matrix multiplication followed
//! by restriction, re-derives the Thomas auxiliary arrays for every line,
//! and carries the `h_l` factors through load vector and solve.
//!
//! Correctness is identical to the contiguous engine (tested to FP rounding)
//! — only the memory behaviour and operation counts differ.

use super::sweeps::{load_mass_restrict, thomas_solve_fresh};
use super::Decomposition;
use crate::error::Result;
use crate::grid::Hierarchy;
use crate::tensor::{strides_for, Scalar, Tensor};

/// Per-level strided geometry.
struct LevelGeom {
    /// Level grid shape.
    shape: Vec<usize>,
    /// Combined stride (level stride × base stride) per dim.
    cs: Vec<usize>,
    /// Which dims halve at this step.
    active: Vec<bool>,
}

fn geom(h: &Hierarchy, l: usize) -> LevelGeom {
    let base = strides_for(h.padded_shape());
    let ls = h.level_stride(l);
    let shape = h.level_shape(l);
    let cs: Vec<usize> = base.iter().zip(&ls).map(|(b, s)| b * s).collect();
    let active = (0..shape.len())
        .map(|d| l >= 1 && h.dim_active(l, d))
        .collect();
    LevelGeom { shape, cs, active }
}

/// Iterate row-major over an index space `sizes`, maintaining the flat offset
/// under `strides`; calls `f(flat, is_all_even_on_active)`.
fn walk(
    sizes: &[usize],
    strides: &[usize],
    active: &[bool],
    mut f: impl FnMut(usize, bool, &[usize]),
) {
    let d = sizes.len();
    let mut idx = vec![0usize; d];
    let total: usize = sizes.iter().product();
    let mut flat = 0usize;
    for _ in 0..total {
        let nodal = (0..d).all(|k| !active[k] || idx[k] % 2 == 0);
        f(flat, nodal, &idx);
        // increment row-major, maintaining the flat offset
        for k in (0..d).rev() {
            idx[k] += 1;
            if idx[k] < sizes[k] {
                flat += strides[k];
                break;
            }
            flat -= strides[k] * (sizes[k] - 1);
            idx[k] = 0;
        }
    }
}

/// Iterate over all line base offsets for a sweep along `dim`: every
/// combination of the other dims' indices under (`sizes`, `strides`).
fn for_each_line(sizes: &[usize], strides: &[usize], dim: usize, mut f: impl FnMut(usize)) {
    let d = sizes.len();
    let mut idx = vec![0usize; d];
    let total: usize = (0..d).map(|k| if k == dim { 1 } else { sizes[k] }).product();
    let mut flat = 0usize;
    for _ in 0..total {
        f(flat);
        for k in (0..d).rev() {
            if k == dim {
                continue;
            }
            idx[k] += 1;
            if idx[k] < sizes[k] {
                flat += strides[k];
                break;
            }
            flat -= strides[k] * (sizes[k] - 1);
            idx[k] = 0;
        }
    }
}

/// Strided residual pass at level `l`: coefficient nodes get their
/// interpolation residual.
fn residual_strided<T: Scalar>(buf: &mut [T], g: &LevelGeom) {
    let d = g.shape.len();
    walk(&g.shape, &g.cs, &g.active, |flat, nodal, idx| {
        if nodal {
            return;
        }
        let mut odd: Vec<usize> = Vec::with_capacity(d);
        for k in 0..d {
            if g.active[k] && idx[k] % 2 == 1 {
                odd.push(g.cs[k]);
            }
        }
        let q = odd.len();
        let mut acc = T::ZERO;
        for mask in 0..(1usize << q) {
            let mut off = flat;
            for (b, &s) in odd.iter().enumerate() {
                if mask & (1 << b) != 0 {
                    off += s;
                } else {
                    off -= s;
                }
            }
            acc += buf[off];
        }
        buf[flat] -= acc * T::from_f64(1.0 / (1usize << q) as f64);
    });
}

/// Inverse of [`residual_strided`].
fn unresidual_strided<T: Scalar>(buf: &mut [T], g: &LevelGeom) {
    let d = g.shape.len();
    walk(&g.shape, &g.cs, &g.active, |flat, nodal, idx| {
        if nodal {
            return;
        }
        let mut odd: Vec<usize> = Vec::with_capacity(d);
        for k in 0..d {
            if g.active[k] && idx[k] % 2 == 1 {
                odd.push(g.cs[k]);
            }
        }
        let q = odd.len();
        let mut acc = T::ZERO;
        for mask in 0..(1usize << q) {
            let mut off = flat;
            for (b, &s) in odd.iter().enumerate() {
                if mask & (1 << b) != 0 {
                    off += s;
                } else {
                    off -= s;
                }
            }
            acc += buf[off];
        }
        buf[flat] += acc * T::from_f64(1.0 / (1usize << q) as f64);
    });
}

/// Compute the correction into `w` at the `N_{l-1}` node positions.
/// `w` is a full-size scratch buffer (the original method's working array).
fn correction_strided<T: Scalar>(buf: &[T], w: &mut [T], g: &LevelGeom, h_level: f64) {
    // 1. multilevel component e into w (zero on nodal nodes)
    walk(&g.shape, &g.cs, &g.active, |flat, nodal, _| {
        w[flat] = if nodal { T::ZERO } else { buf[flat] };
    });
    let d = g.shape.len();
    // 2. load sweeps dim by dim; already-swept dims are at coarse size/stride
    let mut sizes = g.shape.clone();
    let mut strides = g.cs.clone();
    let mut gather: Vec<T> = Vec::new();
    let mut coarse_line: Vec<T> = Vec::new();
    let mut scratch: Vec<T> = Vec::new();
    for k in 0..d {
        if !g.active[k] {
            continue;
        }
        let n = sizes[k];
        let nc = (n + 1) / 2;
        let st = strides[k];
        for_each_line(&sizes, &strides, k, |base| {
            gather.clear();
            gather.extend((0..n).map(|i| w[base + i * st]));
            coarse_line.resize(nc, T::ZERO);
            load_mass_restrict(&gather, &mut coarse_line, h_level, &mut scratch);
            for (i, &v) in coarse_line.iter().enumerate() {
                w[base + 2 * i * st] = v;
            }
        });
        sizes[k] = nc;
        strides[k] = 2 * st;
    }
    // 3. tridiagonal solves along every active dim (coarse geometry now)
    for k in 0..d {
        if !g.active[k] {
            continue;
        }
        let n = sizes[k];
        let st = strides[k];
        for_each_line(&sizes, &strides, k, |base| {
            gather.clear();
            gather.extend((0..n).map(|i| w[base + i * st]));
            thomas_solve_fresh(&mut gather, h_level);
            for (i, &v) in gather.iter().enumerate() {
                w[base + i * st] = v;
            }
        });
    }
}

/// Coarse-node geometry after the step at level `l` (i.e. `N_{l-1}` within
/// the padded array).
fn coarse_geom(g: &LevelGeom) -> (Vec<usize>, Vec<usize>) {
    let sizes = g
        .shape
        .iter()
        .zip(&g.active)
        .map(|(&n, &a)| if a { (n + 1) / 2 } else { n })
        .collect();
    let strides = g
        .cs
        .iter()
        .zip(&g.active)
        .map(|(&s, &a)| if a { 2 * s } else { s })
        .collect();
    (sizes, strides)
}

/// Decompose with the baseline engine.
pub(crate) fn decompose<T: Scalar>(
    hierarchy: &Hierarchy,
    padded: Tensor<T>,
    stop_level: usize,
) -> Decomposition<T> {
    let ll = hierarchy.nlevels();
    let mut buf = padded.into_vec();
    let mut w = vec![T::ZERO; buf.len()];
    for l in ((stop_level + 1)..=ll).rev() {
        let g = geom(hierarchy, l);
        let h_level = hierarchy.spacing(l);
        residual_strided(&mut buf, &g);
        correction_strided(&buf, &mut w, &g, h_level);
        // correction application: nodal nodes += correction
        let (csizes, cstrides) = coarse_geom(&g);
        let no_active = vec![false; csizes.len()];
        walk(&csizes, &cstrides, &no_active, |flat, _, _| {
            buf[flat] += w[flat];
        });
    }
    // extract coarse representation + per-level coefficient streams
    let coarse_shape = hierarchy.level_shape(stop_level);
    let gfin = geom(hierarchy, stop_level);
    let mut coarse = Vec::with_capacity(coarse_shape.iter().product());
    let no_active = vec![false; coarse_shape.len()];
    walk(&coarse_shape, &gfin.cs, &no_active, |flat, _, _| {
        coarse.push(buf[flat]);
    });
    let mut coeffs = Vec::with_capacity(ll - stop_level);
    for l in (stop_level + 1)..=ll {
        let g = geom(hierarchy, l);
        let mut stream = Vec::with_capacity(hierarchy.num_coeff_nodes(l));
        walk(&g.shape, &g.cs, &g.active, |flat, nodal, _| {
            if !nodal {
                stream.push(buf[flat]);
            }
        });
        coeffs.push(stream);
    }
    Decomposition {
        hierarchy: hierarchy.clone(),
        start_level: stop_level,
        coarse: Tensor::from_vec(&coarse_shape, coarse).expect("coarse shape"),
        coeffs,
    }
}

/// Recompose with the baseline engine up to `target_level`.
pub(crate) fn recompose<T: Scalar>(
    hierarchy: &Hierarchy,
    d: &Decomposition<T>,
    target_level: usize,
) -> Result<Tensor<T>> {
    let mut buf = vec![T::ZERO; crate::tensor::numel(hierarchy.padded_shape())];
    let mut w = vec![T::ZERO; buf.len()];
    // scatter the coarse representation
    {
        let g = geom(hierarchy, d.start_level);
        let no_active = vec![false; g.shape.len()];
        let mut k = 0;
        walk(&g.shape, &g.cs, &no_active, |flat, _, _| {
            buf[flat] = d.coarse.data()[k];
            k += 1;
        });
    }
    // scatter all coefficient streams at their node positions
    for l in (d.start_level + 1)..=target_level {
        let g = geom(hierarchy, l);
        let stream = &d.coeffs[l - d.start_level - 1];
        let mut k = 0;
        walk(&g.shape, &g.cs, &g.active, |flat, nodal, _| {
            if !nodal {
                buf[flat] = stream[k];
                k += 1;
            }
        });
    }
    // level-by-level inverse
    for l in (d.start_level + 1)..=target_level {
        let g = geom(hierarchy, l);
        let h_level = hierarchy.spacing(l);
        correction_strided(&buf, &mut w, &g, h_level);
        let (csizes, cstrides) = coarse_geom(&g);
        let no_active = vec![false; csizes.len()];
        walk(&csizes, &cstrides, &no_active, |flat, _, _| {
            buf[flat] -= w[flat];
        });
        unresidual_strided(&mut buf, &g);
    }
    // gather the target level grid
    let tshape = hierarchy.level_shape(target_level);
    let gt = geom(hierarchy, target_level);
    let mut out = Vec::with_capacity(tshape.iter().product());
    let no_active = vec![false; tshape.len()];
    walk(&tshape, &gt.cs, &no_active, |flat, _, _| {
        out.push(buf[flat]);
    });
    Ok(Tensor::from_vec(&tshape, out).expect("target shape"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::decompose::{contiguous, OptFlags};

    fn rand_tensor(shape: &[usize], seed: u64) -> Tensor<f64> {
        let mut rng = Rng::new(seed);
        Tensor::from_fn(shape, |_| rng.uniform_in(-1.0, 1.0))
    }

    #[test]
    fn baseline_round_trip() {
        for shape in [vec![17usize], vec![9, 17], vec![9, 9, 9], vec![5, 5, 5, 5]] {
            let h = Hierarchy::new(&shape, None).unwrap();
            let u = rand_tensor(&shape, 7);
            let dec = decompose(&h, h.pad(&u).unwrap(), 0);
            dec.validate().unwrap();
            let back = recompose(&h, &dec, h.nlevels()).unwrap();
            let back = h.crop(&back).unwrap();
            let err = crate::metrics::linf_error(u.data(), back.data());
            assert!(err < 1e-9, "{shape:?}: {err}");
        }
    }

    #[test]
    fn baseline_matches_contiguous_engine() {
        for shape in [vec![17usize], vec![9, 17], vec![9, 9, 9], vec![6, 11]] {
            let h = Hierarchy::new(&shape, None).unwrap();
            let u = rand_tensor(&shape, 19);
            let a = decompose(&h, h.pad(&u).unwrap(), 0);
            let b = contiguous::decompose(&h, OptFlags::all(), h.pad(&u).unwrap(), 0);
            assert_eq!(a.coarse.shape(), b.coarse.shape());
            for (x, y) in a.coarse.data().iter().zip(b.coarse.data()) {
                assert!((x - y).abs() < 1e-9, "coarse {x} vs {y} ({shape:?})");
            }
            for (ka, kb) in a.coeffs.iter().zip(&b.coeffs) {
                assert_eq!(ka.len(), kb.len());
                for (x, y) in ka.iter().zip(kb) {
                    assert!((x - y).abs() < 1e-9, "coeff {x} vs {y} ({shape:?})");
                }
            }
        }
    }

    #[test]
    fn baseline_partial_matches_contiguous() {
        let shape = [17, 17];
        let h = Hierarchy::new(&shape, None).unwrap();
        let u = rand_tensor(&shape, 23);
        let a = decompose(&h, h.pad(&u).unwrap(), 1);
        let b = contiguous::decompose(&h, OptFlags::all(), h.pad(&u).unwrap(), 1);
        for (x, y) in a.coarse.data().iter().zip(b.coarse.data()) {
            assert!((x - y).abs() < 1e-9);
        }
        // cross-engine recompose: baseline-decomposed, contiguous-recomposed
        let back = contiguous::recompose(&h, OptFlags::all(), &a, h.nlevels()).unwrap();
        let err = crate::metrics::linf_error(h.pad(&u).unwrap().data(), back.data());
        assert!(err < 1e-9, "cross engine {err}");
    }

    #[test]
    fn recompose_to_intermediate_level() {
        let shape = [17, 9];
        let h = Hierarchy::new(&shape, None).unwrap();
        let u = rand_tensor(&shape, 29);
        let dec = decompose(&h, h.pad(&u).unwrap(), 0);
        let q1 = recompose(&h, &dec, 1).unwrap();
        let q1c = contiguous::recompose(&h, OptFlags::all(), &dec, 1).unwrap();
        assert_eq!(q1.shape(), h.level_shape(1).as_slice());
        let err = crate::metrics::linf_error(q1.data(), q1c.data());
        assert!(err < 1e-9);
    }
}
