//! The fused decompose→quantize hot path.
//!
//! The staged MGARD+ pipeline materializes one full coefficient buffer per
//! level, then re-reads every buffer in a second pass to quantize it. This
//! module fuses the two: [`decompose_quantize`] runs the contiguous engine
//! with a [`crate::quant::QuantSink`] as the [`super::CoeffSink`], so each
//! coefficient is mapped to its quantizer symbol the moment `split_level`
//! compacts it out of the level array — the per-level scalar buffers (and
//! the second pass over them) disappear, exactly the kernel-fusion move the
//! GPU refactoring line of work applies to reach memory-bound throughput.
//!
//! # Invariants
//!
//! * **Bit identity** — the merged symbol/escape stream is byte-for-byte
//!   the one the staged path (decompose, then [`crate::quant::quantize`]
//!   per level, coarsest first) produces: both run the same per-value
//!   quantization in the same canonical order, only the buffering differs.
//!   Enforced by the differential suite in
//!   `rust/tests/decompose_equivalence.rs`.
//! * **Static schedule** — `tiers[l]` is the tolerance of level `l`'s
//!   coefficients and must be known before the first step, which is why
//!   the adaptive-termination path (stop level unknown until the loop
//!   ends) stays staged (see [`OptFlags::fused`]).
//! * **O(1) allocations** — all working memory comes from the caller's
//!   [`DecomposeScratch`] and [`FusedStreams`]; in steady state the pass
//!   allocates nothing beyond what escapes into the returned coarse
//!   tensor.

use super::contiguous::{step_decompose_into, DecomposeScratch};
use super::OptFlags;
use crate::grid::Hierarchy;
use crate::quant::{QuantSink, QuantStream};
use crate::tensor::{Scalar, Tensor};

/// Reusable per-level + merged quantizer streams of the fused pass.
///
/// Levels are quantized finest-first (the order decomposition produces
/// them) into pooled per-level streams, then merged coarsest-first into
/// [`FusedStreams::merged`] — the container's canonical stream order.
#[derive(Default)]
pub struct FusedStreams {
    levels: Vec<QuantStream>,
    /// The merged symbol/escape stream, coarsest level first (identical to
    /// the staged quantization order).
    pub merged: QuantStream,
}

impl FusedStreams {
    /// Fresh, empty pool.
    pub fn new() -> Self {
        FusedStreams::default()
    }

    fn ensure(&mut self, nlevels: usize) {
        while self.levels.len() < nlevels {
            self.levels.push(QuantStream::default());
        }
    }
}

/// Fully decompose `padded` (stop level 0) with the contiguous engine,
/// quantizing each level's coefficients as they are compacted.
///
/// `tiers[l]` is the quantization tolerance of level `l` for
/// `l in 1..=hierarchy.nlevels()` (`tiers[0]`, the coarse tier, is owned by
/// the external compressor and ignored here), so `tiers.len()` must be
/// `nlevels + 1`. Returns the coarse representation; the merged
/// symbol/escape stream is left in `streams.merged`.
pub fn decompose_quantize<T: Scalar>(
    hierarchy: &Hierarchy,
    flags: OptFlags,
    padded: Tensor<T>,
    tiers: &[f64],
    scratch: &mut DecomposeScratch<T>,
    streams: &mut FusedStreams,
) -> Tensor<T> {
    let ll = hierarchy.nlevels();
    debug_assert_eq!(tiers.len(), ll + 1, "one tier per level plus the coarse tier");
    streams.ensure(ll);
    let mut cur = padded.into_vec();
    let mut shape = hierarchy.padded_shape().to_vec();
    for l in (1..=ll).rev() {
        let qs = &mut streams.levels[ll - l];
        qs.symbols.clear();
        qs.escapes.clear();
        let mut sink = QuantSink::new(tiers[l], qs);
        shape = step_decompose_into(
            &mut cur,
            &shape,
            flags,
            hierarchy.spacing(l),
            scratch,
            &mut sink,
        );
        debug_assert_eq!(shape, hierarchy.level_shape(l - 1));
    }
    // merge coarsest level first — the staged layout the container stores
    let merged = &mut streams.merged;
    merged.symbols.clear();
    merged.escapes.clear();
    for qs in streams.levels[..ll].iter().rev() {
        merged.symbols.extend_from_slice(&qs.symbols);
        merged.escapes.extend_from_slice(&qs.escapes);
    }
    Tensor::from_vec(&shape, cur).expect("coarse shape consistent")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::contiguous;
    use crate::quant::{level_tolerances, quantize, DEFAULT_C_LINF};

    /// The fused pass must reproduce the staged decompose-then-quantize
    /// symbol/escape stream bit-for-bit.
    fn check(shape: &[usize], tau: f64, seed: u64) {
        let mut rng = crate::data::rng::Rng::new(seed);
        let u = Tensor::<f64>::from_fn(shape, |_| rng.uniform_in(-1.0, 1.0));
        let h = Hierarchy::new(shape, None).unwrap();
        let ll = h.nlevels();
        let tiers = level_tolerances(ll + 1, shape.len(), tau, DEFAULT_C_LINF);

        // staged oracle
        let dec = contiguous::decompose(&h, OptFlags::all_staged(), h.pad(&u).unwrap(), 0);
        let mut staged = QuantStream::default();
        for (i, stream) in dec.coeffs.iter().enumerate() {
            quantize(stream, tiers[i + 1], &mut staged);
        }

        // fused pass
        let mut scratch = DecomposeScratch::new();
        let mut streams = FusedStreams::new();
        let coarse = decompose_quantize(
            &h,
            OptFlags::all(),
            h.pad(&u).unwrap(),
            &tiers,
            &mut scratch,
            &mut streams,
        );
        assert_eq!(coarse.data(), dec.coarse.data(), "{shape:?}: coarse differs");
        assert_eq!(
            streams.merged.symbols, staged.symbols,
            "{shape:?}: symbol streams differ"
        );
        assert_eq!(
            streams.merged.escapes, staged.escapes,
            "{shape:?}: escape channels differ"
        );
    }

    #[test]
    fn fused_matches_staged_quantization() {
        check(&[33], 1e-3, 1);
        check(&[17, 9], 1e-4, 2);
        check(&[9, 10, 11], 1e-3, 3);
    }

    #[test]
    fn fused_reuses_streams_across_fields() {
        // one FusedStreams pool across different shapes must not leak state
        let mut scratch = DecomposeScratch::new();
        let mut streams = FusedStreams::new();
        for (i, shape) in [&[17usize, 17][..], &[9][..], &[6, 10, 11][..]]
            .iter()
            .enumerate()
        {
            let mut rng = crate::data::rng::Rng::new(50 + i as u64);
            let u = Tensor::<f64>::from_fn(shape, |_| rng.uniform_in(-2.0, 2.0));
            let h = Hierarchy::new(shape, None).unwrap();
            let tiers = level_tolerances(h.nlevels() + 1, shape.len(), 1e-3, DEFAULT_C_LINF);
            let _ = decompose_quantize(
                &h,
                OptFlags::all(),
                h.pad(&u).unwrap(),
                &tiers,
                &mut scratch,
                &mut streams,
            );
            let reused_syms = streams.merged.symbols.clone();
            let mut fresh = (DecomposeScratch::new(), FusedStreams::new());
            let _ = decompose_quantize(
                &h,
                OptFlags::all(),
                h.pad(&u).unwrap(),
                &tiers,
                &mut fresh.0,
                &mut fresh.1,
            );
            assert_eq!(reused_syms, fresh.1.merged.symbols, "{shape:?}");
        }
    }
}
