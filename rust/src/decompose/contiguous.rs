//! The MGARD+ engine: level-centric reordered (contiguous) multilevel
//! decomposition with the §5 optimizations.
//!
//! Instead of striding over the full array with ever-growing strides, each
//! level works on a *contiguous* array holding exactly the current grid
//! `N_l` (the de-interleaving view of §5.1): coefficient computation and
//! correction run cache-coherently, then the nodal nodes are compacted into
//! a new contiguous array for the next level while the coefficient nodes are
//! emitted to a [`CoeffSink`] — a `Vec` for the staged path, or the
//! level-wise quantizer directly for the fused decompose→quantize hot path
//! (see [`super::fused`]).
//!
//! All intermediate buffers (Thomas factorizations, sweep ping-pong arrays,
//! level/coarse compaction buffers, gather/scatter columns) live in a
//! [`DecomposeScratch`] that is allocated once and reused across levels,
//! calls and — via the chunk worker pool — across blocks, so steady-state
//! compression performs O(1) heap allocations per block.

use super::sweeps::{
    load_direct, load_direct_panel, load_mass_restrict, load_mass_restrict_panel,
    thomas_solve_fresh, LinePanel, ThomasAux,
};
use super::{CoeffSink, Decomposition, OptFlags};
use crate::error::Result;
use crate::grid::Hierarchy;
use crate::tensor::{numel, Scalar, Tensor};
use std::collections::BTreeMap;

/// Thomas factorizations keyed by coarse length (IVER's precomputed
/// auxiliary arrays, shared across levels, dims, and — through
/// [`DecomposeScratch`] — across blocks).
struct AuxCache<T: Scalar> {
    map: BTreeMap<usize, ThomasAux<T>>,
}

impl<T: Scalar> AuxCache<T> {
    fn new() -> Self {
        AuxCache {
            map: BTreeMap::new(),
        }
    }
    fn get(&mut self, n: usize) -> &ThomasAux<T> {
        self.map.entry(n).or_insert_with(|| ThomasAux::new(n, 1.0))
    }
}

/// Column gather/scatter and per-line buffers of the strided (pre-BCC)
/// sweep paths.
struct LineBufs<T: Scalar> {
    col_in: Vec<T>,
    col_out: Vec<T>,
    mass: Vec<T>,
}

impl<T: Scalar> LineBufs<T> {
    fn new() -> Self {
        LineBufs {
            col_in: Vec::new(),
            col_out: Vec::new(),
            mass: Vec::new(),
        }
    }
}

/// Default width (in lines, or stride-1 lanes for non-unit-stride axes) of
/// the panel the batched sweep kernels process per pass. 64 lanes keeps a
/// row pair of an f64 panel within a handful of cache lines while giving
/// the auto-vectorizer long stride-1 inner loops.
pub const DEFAULT_PANEL_WIDTH: usize = 64;

/// Reusable workspace of the contiguous engine.
///
/// One scratch serves any number of sequential [`decompose_scratch`] /
/// [`recompose_scratch`] / [`step_decompose_into`] calls, on any shapes and
/// scalar streams of the same `T`; buffers grow to the high-water mark and
/// are reused, so a chunk worker that threads one scratch through every
/// block it compresses performs O(1) heap allocations per block in steady
/// state.
///
/// # Invariants
///
/// * Reuse is **value-transparent**: the transform output is bit-identical
///   whether a scratch is fresh, reused across levels, or reused across
///   unrelated fields/blocks (enforced by `rust/tests/alloc_budget.rs` and
///   the differential suite in `rust/tests/decompose_equivalence.rs`).
/// * The scratch carries no data dependencies between calls — only
///   capacity and the [`ThomasAux`] factorizations, which are pure
///   functions of the line length.
/// * [`panel_width`](Self::panel_width) is likewise value-transparent:
///   every panel kernel performs the identical per-element operation
///   sequence for every width, so any two widths (including 1, the
///   per-line oracle) produce bit-identical transforms (enforced by
///   `rust/tests/panel_differential.rs`). It is a *tuning* knob, never a
///   semantic one.
/// * A scratch is single-threaded state: share one per worker, never one
///   across workers.
pub struct DecomposeScratch<T: Scalar> {
    aux: AuxCache<T>,
    /// Sweep ping-pong buffers; `correction` leaves its result in `work_a`.
    work_a: Vec<T>,
    work_b: Vec<T>,
    /// Coarse compaction buffer of `split_level`, swapped with the level
    /// array each step.
    coarse: Vec<T>,
    /// Fine-level buffer of the recomposition side (scatter + merge).
    level: Vec<T>,
    lines: LineBufs<T>,
    /// Transpose-gather tile of the line-batched sweep paths.
    panel: LinePanel<T>,
    /// Panel width of the batched sweep kernels: the number of contiguous
    /// lines gathered per tile on unit-stride axes, and the column-panel
    /// width (stride-1 lanes) the cache-blocked kernels touch per pass on
    /// non-unit-stride axes. Value-transparent (see the invariants above);
    /// `1` forces the per-line reference path, widths beyond the line
    /// count are clamped per panel.
    pub panel_width: usize,
}

impl<T: Scalar> DecomposeScratch<T> {
    /// Fresh, empty workspace with the default panel width.
    pub fn new() -> Self {
        DecomposeScratch::with_panel_width(DEFAULT_PANEL_WIDTH)
    }

    /// Fresh, empty workspace with an explicit panel width (`1` forces the
    /// per-line reference path; the differential suite sweeps this knob).
    pub fn with_panel_width(panel_width: usize) -> Self {
        DecomposeScratch {
            aux: AuxCache::new(),
            work_a: Vec::new(),
            work_b: Vec::new(),
            coarse: Vec::new(),
            level: Vec::new(),
            lines: LineBufs::new(),
            panel: LinePanel::new(),
            panel_width: panel_width.max(1),
        }
    }
}

impl<T: Scalar> Default for DecomposeScratch<T> {
    fn default() -> Self {
        DecomposeScratch::new()
    }
}

/// Which dims halve at this step (size >= 5 still halves; 3 has bottomed out).
fn active_dims(shape: &[usize]) -> Vec<bool> {
    shape.iter().map(|&n| n >= 5).collect()
}

/// In-place coefficient computation: replace every coefficient-node value by
/// its residual against the multilinear interpolant of the nodal nodes.
/// `shape` is the current contiguous level grid.
///
/// The 3-D all-active case (the bulk of every decomposition) is specialized:
/// the generic path pays a per-element parity test and corner-mask loop,
/// while the specialization classifies whole z-lines by the (x, y) parity
/// and runs branch-free stride-2 stencils (§Perf in EXPERIMENTS.md).
pub(crate) fn residual_pass<T: Scalar>(data: &mut [T], shape: &[usize]) {
    if shape.len() == 3 && shape.iter().all(|&n| n >= 5) {
        return residual_pass_3d(data, shape, false);
    }
    residual_pass_generic(data, shape);
}

/// Specialized 3-D residual pass; `inverse` adds the interpolant back.
fn residual_pass_3d<T: Scalar>(data: &mut [T], shape: &[usize], inverse: bool) {
    let (n0, n1, n2) = (shape[0], shape[1], shape[2]);
    let s0 = n1 * n2;
    let half = T::from_f64(0.5);
    let quarter = T::from_f64(0.25);
    let eighth = T::from_f64(0.125);
    // apply `v -= pred` or `v += pred`
    macro_rules! upd {
        ($slot:expr, $pred:expr) => {
            if inverse {
                $slot += $pred;
            } else {
                $slot -= $pred;
            }
        };
    }
    for x in 0..n0 {
        for y in 0..n1 {
            let base = x * s0 + y * n2;
            match (x % 2, y % 2) {
                (0, 0) => {
                    // nodal row: only odd-z (edge) nodes change
                    let mut z = 1;
                    while z < n2 - 1 {
                        let pred = half * (data[base + z - 1] + data[base + z + 1]);
                        upd!(data[base + z], pred);
                        z += 2;
                    }
                }
                (1, 0) | (0, 1) => {
                    // one odd planar dim: neighbors are the two nodal rows
                    let nb = if x % 2 == 1 { s0 } else { n2 };
                    let (lo, hi) = (base - nb, base + nb);
                    // even z: face nodes on the x/y edge
                    let mut z = 0;
                    while z < n2 {
                        let pred = half * (data[lo + z] + data[hi + z]);
                        upd!(data[base + z], pred);
                        z += 2;
                    }
                    // odd z: plane nodes (4 corners)
                    let mut z = 1;
                    while z < n2 - 1 {
                        let pred = quarter
                            * (data[lo + z - 1]
                                + data[lo + z + 1]
                                + data[hi + z - 1]
                                + data[hi + z + 1]);
                        upd!(data[base + z], pred);
                        z += 2;
                    }
                }
                _ => {
                    // x and y both odd: 4 nodal rows at the (x±1, y±1) corners
                    let r00 = base - s0 - n2;
                    let r01 = base - s0 + n2;
                    let r10 = base + s0 - n2;
                    let r11 = base + s0 + n2;
                    let mut z = 0;
                    while z < n2 {
                        let pred = quarter
                            * (data[r00 + z] + data[r01 + z] + data[r10 + z] + data[r11 + z]);
                        upd!(data[base + z], pred);
                        z += 2;
                    }
                    let mut z = 1;
                    while z < n2 - 1 {
                        let pred = eighth
                            * (data[r00 + z - 1]
                                + data[r00 + z + 1]
                                + data[r01 + z - 1]
                                + data[r01 + z + 1]
                                + data[r10 + z - 1]
                                + data[r10 + z + 1]
                                + data[r11 + z - 1]
                                + data[r11 + z + 1]);
                        upd!(data[base + z], pred);
                        z += 2;
                    }
                }
            }
        }
    }
}

fn residual_pass_generic<T: Scalar>(data: &mut [T], shape: &[usize]) {
    let active = active_dims(shape);
    let strides = crate::tensor::strides_for(shape);
    let d = shape.len();
    let mut idx = vec![0usize; d];
    let n = data.len();
    // odd_dims: strides of dims where the index is odd (active only)
    let mut odd: Vec<usize> = Vec::with_capacity(d);
    for flat in 0..n {
        odd.clear();
        for k in 0..d {
            if active[k] && idx[k] % 2 == 1 {
                odd.push(strides[k]);
            }
        }
        let q = odd.len();
        if q > 0 {
            // average of the 2^q corners
            let mut acc = T::ZERO;
            for mask in 0..(1usize << q) {
                let mut off = flat;
                for (b, &s) in odd.iter().enumerate() {
                    if mask & (1 << b) != 0 {
                        off += s;
                    } else {
                        off -= s;
                    }
                }
                acc += data[off];
            }
            let w = T::from_f64(1.0 / (1usize << q) as f64);
            data[flat] -= acc * w;
        }
        // increment multi-index
        for k in (0..d).rev() {
            idx[k] += 1;
            if idx[k] < shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
}

/// Inverse of [`residual_pass`]: add interpolant back to residuals.
fn unresidual_pass<T: Scalar>(data: &mut [T], shape: &[usize]) {
    if shape.len() == 3 && shape.iter().all(|&n| n >= 5) {
        return residual_pass_3d(data, shape, true);
    }
    unresidual_pass_generic(data, shape);
}

fn unresidual_pass_generic<T: Scalar>(data: &mut [T], shape: &[usize]) {
    let active = active_dims(shape);
    let strides = crate::tensor::strides_for(shape);
    let d = shape.len();
    let mut idx = vec![0usize; d];
    let n = data.len();
    let mut odd: Vec<usize> = Vec::with_capacity(d);
    for flat in 0..n {
        odd.clear();
        for k in 0..d {
            if active[k] && idx[k] % 2 == 1 {
                odd.push(strides[k]);
            }
        }
        let q = odd.len();
        if q > 0 {
            let mut acc = T::ZERO;
            for mask in 0..(1usize << q) {
                let mut off = flat;
                for (b, &s) in odd.iter().enumerate() {
                    if mask & (1 << b) != 0 {
                        off += s;
                    } else {
                        off -= s;
                    }
                }
                acc += data[off];
            }
            let w = T::from_f64(1.0 / (1usize << q) as f64);
            data[flat] += acc * w;
        }
        for k in (0..d).rev() {
            idx[k] += 1;
            if idx[k] < shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
}

/// Fill `out` with a copy of the level array whose nodal positions are
/// zeroed: the multilevel component `e = (I - Π) Q_l u`, zero on `N_{l-1}`.
fn multilevel_component<T: Scalar>(data: &[T], shape: &[usize], out: &mut Vec<T>) {
    let active = active_dims(shape);
    let d = shape.len();
    out.clear();
    out.extend_from_slice(data);
    let mut idx = vec![0usize; d];
    for item in out.iter_mut() {
        let nodal = (0..d).all(|k| !active[k] || idx[k] % 2 == 0);
        if nodal {
            *item = T::ZERO;
        }
        for k in (0..d).rev() {
            idx[k] += 1;
            if idx[k] < shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
}

/// Load sweep along `dim`: consumes an array of `shape`, fills `out` with
/// the array whose `shape[dim]` is halved (load vector contributions along
/// that dim) and returns the halved shape. Every element of `out` is
/// overwritten.
///
/// With `flags.batched` the sweep is **line-batched and cache-blocked**
/// (panel width `s.panel_width`): unit-stride axes transpose-gather a panel
/// of contiguous lines into the lane-interleaved [`LinePanel`] tile and run
/// the panel kernels, non-unit-stride axes walk the stencil in stride-1
/// column panels. Every path performs the identical per-element arithmetic,
/// so the output is bit-identical for every panel width (width 1 is the
/// per-line reference).
fn load_sweep<T: Scalar>(
    input: &[T],
    shape: &[usize],
    dim: usize,
    flags: OptFlags,
    h: f64,
    out: &mut Vec<T>,
    s: &mut DecomposeScratch<T>,
) -> Vec<usize> {
    let n = shape[dim];
    let nc = (n + 1) / 2;
    let outer: usize = shape[..dim].iter().product();
    let inner: usize = shape[dim + 1..].iter().product();
    let mut out_shape = shape.to_vec();
    out_shape[dim] = nc;
    out.clear();
    out.resize(outer * nc * inner, T::ZERO);
    let pw = s.panel_width.max(1);

    if inner == 1 {
        if flags.batched && pw > 1 {
            // line-batched: transpose-gather panels of contiguous lines and
            // run the lane-interleaved kernels (stride-1 inner loops over
            // the panel, no per-line bounds checks)
            let panel = &mut s.panel;
            let mut o0 = 0;
            while o0 < outer {
                let bw = pw.min(outer - o0);
                panel.gather(input, o0, n, bw);
                panel.ensure_out(nc, bw);
                if flags.direct_load {
                    load_direct_panel(&panel.tile_in, &mut panel.tile_out, bw, h);
                } else {
                    load_mass_restrict_panel(
                        &panel.tile_in,
                        &mut panel.tile_out,
                        bw,
                        h,
                        &mut panel.mass,
                    );
                }
                panel.scatter_out(out, o0, nc, bw);
                o0 += bw;
            }
        } else {
            // contiguous lines along the last dim, one at a time
            for o in 0..outer {
                let line = &input[o * n..(o + 1) * n];
                let dst = &mut out[o * nc..(o + 1) * nc];
                if flags.direct_load {
                    load_direct(line, dst, h);
                } else {
                    load_mass_restrict(line, dst, h, &mut s.lines.mass);
                }
            }
        }
    } else if flags.batched {
        // vectorized direct stencil over the contiguous inner dimension,
        // cache-blocked into column panels of `pw` stride-1 lanes so the
        // five input rows under the stencil stay resident per panel
        let wo = T::from_f64(h / 12.0);
        let wm = T::from_f64(h * 0.5);
        let wc = T::from_f64(h * 5.0 / 6.0);
        let wb = T::from_f64(h * 5.0 / 12.0);
        for o in 0..outer {
            let src = &input[o * n * inner..(o + 1) * n * inner];
            let dst = &mut out[o * nc * inner..(o + 1) * nc * inner];
            let mut j0 = 0;
            while j0 < inner {
                let jw = pw.min(inner - j0);
                // i = 0: wb*c0 + wm*c1 + wo*c2
                {
                    let rows = &src[j0..2 * inner + j0 + jw];
                    let d0 = &mut dst[j0..j0 + jw];
                    for j in 0..jw {
                        d0[j] = wb * rows[j] + wm * rows[inner + j] + wo * rows[2 * inner + j];
                    }
                }
                for i in 1..nc - 1 {
                    let k = 2 * i;
                    let base = (k - 2) * inner + j0;
                    let rows = &src[base..base + 4 * inner + jw];
                    let d = &mut dst[i * inner + j0..i * inner + j0 + jw];
                    for j in 0..jw {
                        d[j] = wo * rows[j]
                            + wm * rows[inner + j]
                            + wc * rows[2 * inner + j]
                            + wm * rows[3 * inner + j]
                            + wo * rows[4 * inner + j];
                    }
                }
                // i = nc-1
                {
                    let base = (n - 3) * inner + j0;
                    let rows = &src[base..base + 2 * inner + jw];
                    let d = &mut dst[(nc - 1) * inner + j0..(nc - 1) * inner + j0 + jw];
                    for j in 0..jw {
                        d[j] = wo * rows[j] + wm * rows[inner + j] + wb * rows[2 * inner + j];
                    }
                }
                j0 += jw;
            }
        }
    } else {
        // column-at-a-time with strided gather/scatter (the pre-BCC pattern)
        let lines = &mut s.lines;
        lines.col_in.clear();
        lines.col_in.resize(n, T::ZERO);
        lines.col_out.clear();
        lines.col_out.resize(nc, T::ZERO);
        for o in 0..outer {
            let src_base = o * n * inner;
            let dst_base = o * nc * inner;
            for j in 0..inner {
                for i in 0..n {
                    lines.col_in[i] = input[src_base + i * inner + j];
                }
                if flags.direct_load {
                    load_direct(&lines.col_in, &mut lines.col_out, h);
                } else {
                    load_mass_restrict(&lines.col_in, &mut lines.col_out, h, &mut lines.mass);
                }
                for i in 0..nc {
                    out[dst_base + i * inner + j] = lines.col_out[i];
                }
            }
        }
    }
    out_shape
}

/// Tridiagonal mass solve along `dim` (in place).
///
/// With `flags.batched` the solve is line-batched and cache-blocked like
/// [`load_sweep`]: unit-stride axes solve transpose-gathered line panels
/// via [`ThomasAux::solve_batch`], non-unit-stride axes run the blocked
/// [`ThomasAux::solve_batch_blocked`] over `s.panel_width`-lane column
/// panels. All paths are bit-identical to the per-line solve.
fn mass_solve<T: Scalar>(
    data: &mut [T],
    shape: &[usize],
    dim: usize,
    flags: OptFlags,
    h: f64,
    s: &mut DecomposeScratch<T>,
) {
    let n = shape[dim];
    let outer: usize = shape[..dim].iter().product();
    let inner: usize = shape[dim + 1..].iter().product();
    let pw = s.panel_width.max(1);
    if inner == 1 {
        if flags.batched && pw > 1 {
            // line-batched: solve a transposed panel of contiguous lines at
            // a time (the forward/backward recurrences vectorize over the
            // panel lanes)
            let mut o0 = 0;
            while o0 < outer {
                let bw = pw.min(outer - o0);
                s.panel.gather(data, o0, n, bw);
                if flags.reuse {
                    let a = s.aux.get(n);
                    a.solve_batch(&mut s.panel.tile_in, bw);
                } else {
                    ThomasAux::<T>::new(n, h).solve_batch(&mut s.panel.tile_in, bw);
                }
                s.panel.scatter_in(data, o0, n, bw);
                o0 += bw;
            }
        } else if flags.reuse {
            let a = s.aux.get(n);
            for o in 0..outer {
                a.solve(&mut data[o * n..(o + 1) * n]);
            }
        } else {
            for o in 0..outer {
                thomas_solve_fresh(&mut data[o * n..(o + 1) * n], h);
            }
        }
    } else if flags.batched {
        if flags.reuse {
            let a = s.aux.get(n);
            for o in 0..outer {
                a.solve_batch_blocked(&mut data[o * n * inner..(o + 1) * n * inner], inner, pw);
            }
        } else {
            let a = ThomasAux::<T>::new(n, h);
            for o in 0..outer {
                a.solve_batch_blocked(&mut data[o * n * inner..(o + 1) * n * inner], inner, pw);
            }
        }
    } else {
        let aux = &mut s.aux;
        let col = &mut s.lines.col_in;
        col.clear();
        col.resize(n, T::ZERO);
        for o in 0..outer {
            let base = o * n * inner;
            for j in 0..inner {
                for i in 0..n {
                    col[i] = data[base + i * inner + j];
                }
                if flags.reuse {
                    aux.get(n).solve(col);
                } else {
                    thomas_solve_fresh(col, h);
                }
                for i in 0..n {
                    data[base + i * inner + j] = col[i];
                }
            }
        }
    }
}

/// First load sweep fused with the nodal mask: reads the residualized level
/// array directly (even-everywhere entries are implicitly zero) and sweeps
/// along the *last* (contiguous) dimension. This is the IVER elimination of
/// the intermediate multilevel-component array (§5.4): one full-array copy
/// and one full-array write vanish. Fills `out` (every element overwritten)
/// and returns the halved shape.
fn load_sweep_last_masked<T: Scalar>(
    input: &[T],
    shape: &[usize],
    active: &[bool],
    out: &mut Vec<T>,
) -> Vec<usize> {
    let d = shape.len();
    let n = shape[d - 1];
    let nc = (n + 1) / 2;
    let outer: usize = shape[..d - 1].iter().product();
    let mut out_shape = shape.to_vec();
    out_shape[d - 1] = nc;
    out.clear();
    out.resize(outer * nc, T::ZERO);
    let wo = T::from_f64(1.0 / 12.0);
    let wm = T::from_f64(0.5);
    let wc = T::from_f64(5.0 / 6.0);
    let wb = T::from_f64(5.0 / 12.0);
    let mut idx = vec![0usize; d.saturating_sub(1)];
    for o in 0..outer {
        let others_even = (0..d - 1).all(|k| !active[k] || idx[k] % 2 == 0);
        let line = &input[o * n..(o + 1) * n];
        let dst = &mut out[o * nc..(o + 1) * nc];
        if others_even {
            // nodal (even) entries of e are zero: only the odd taps remain
            dst[0] = wm * line[1];
            for i in 1..nc - 1 {
                let k = 2 * i;
                dst[i] = wm * (line[k - 1] + line[k + 1]);
            }
            dst[nc - 1] = wm * line[n - 2];
        } else {
            // every entry on this line is a coefficient node
            dst[0] = wb * line[0] + wm * line[1] + wo * line[2];
            for i in 1..nc - 1 {
                let k = 2 * i;
                dst[i] = wo * line[k - 2]
                    + wm * line[k - 1]
                    + wc * line[k]
                    + wm * line[k + 1]
                    + wo * line[k + 2];
            }
            dst[nc - 1] = wo * line[n - 3] + wm * line[n - 2] + wb * line[n - 1];
        }
        for k in (0..d - 1).rev() {
            idx[k] += 1;
            if idx[k] < shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
    out_shape
}

/// Compute the correction `Q_{l-1}(I-Π)Q_l u` from the residualized level
/// array: load sweeps along every active dim, then mass solves. The result
/// is left in `s.work_a`; its shape is returned.
fn correction<T: Scalar>(
    level_data: &[T],
    shape: &[usize],
    flags: OptFlags,
    h_level: f64,
    s: &mut DecomposeScratch<T>,
) -> Vec<usize> {
    let active = active_dims(shape);
    let d = shape.len();
    // the h factors cancel against the mass solve; the non-IVER path carries
    // them through both stages like the original implementation
    let h = if flags.reuse { 1.0 } else { h_level };
    // ping-pong between the two sweep buffers; `a` always holds the latest
    let mut a = std::mem::take(&mut s.work_a);
    let mut b = std::mem::take(&mut s.work_b);
    let mut wshape;
    if flags.reuse && flags.direct_load && active[d - 1] {
        // IVER fast path: fused mask + last-dim sweep, no e-copy
        wshape = load_sweep_last_masked(level_data, shape, &active, &mut a);
        for k in 0..d - 1 {
            if active[k] {
                wshape = load_sweep(&a, &wshape, k, flags, h, &mut b, s);
                std::mem::swap(&mut a, &mut b);
            }
        }
    } else {
        multilevel_component(level_data, shape, &mut a);
        wshape = shape.to_vec();
        for k in 0..d {
            if active[k] {
                wshape = load_sweep(&a, &wshape, k, flags, h, &mut b, s);
                std::mem::swap(&mut a, &mut b);
            }
        }
    }
    for k in 0..d {
        if active[k] {
            mass_solve(&mut a, &wshape, k, flags, h, s);
        }
    }
    s.work_a = a;
    s.work_b = b;
    wshape
}

/// Correction of a given multilevel component in isolation (exposed for the
/// §4.2.2 penalty-factor calibration, which measures the statistical spread
/// of corrections induced by coefficient-node noise).
pub(crate) fn correction_of_component(e: &[f64], shape: &[usize], flags: OptFlags) -> Vec<f64> {
    let mut s = DecomposeScratch::new();
    let _ = correction(e, shape, flags, 1.0, &mut s);
    s.work_a
}

/// De-interleave one level: compact the nodal values (plus correction) into
/// `coarse` and emit the coefficient nodes to `sink` in canonical
/// (row-major) order. `corr` is the correction on grid `cshape`.
fn split_level<T: Scalar, S: CoeffSink<T> + ?Sized>(
    data: &[T],
    shape: &[usize],
    corr: &[T],
    cshape: &[usize],
    coarse: &mut Vec<T>,
    sink: &mut S,
) {
    let active = active_dims(shape);
    let d = shape.len();
    let n = shape[d - 1];
    let last_active = active[d - 1];
    let outer: usize = shape[..d - 1].iter().product();
    coarse.clear();
    let mut idx = vec![0usize; d.saturating_sub(1)];
    // line-at-a-time: a whole z-line is coefficient data unless every other
    // active dim is even; the canonical (row-major) order is preserved
    for o in 0..outer {
        let others_even = (0..d - 1).all(|k| !active[k] || idx[k] % 2 == 0);
        let line = &data[o * n..(o + 1) * n];
        if !others_even {
            sink.run(line);
        } else if last_active {
            for (z, &v) in line.iter().enumerate() {
                if z % 2 == 0 {
                    let cflat = coarse.len();
                    coarse.push(v + corr[cflat]);
                } else {
                    sink.push(v);
                }
            }
        } else {
            // last dim bottomed out: the whole line is nodal
            for &v in line {
                let cflat = coarse.len();
                coarse.push(v + corr[cflat]);
            }
        }
        for k in (0..d - 1).rev() {
            idx[k] += 1;
            if idx[k] < shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
    debug_assert_eq!(coarse.len(), numel(cshape));
}

/// Inverse of [`split_level`]: interleave coarse (minus correction) and
/// coefficients back into the fine contiguous array `fine`, then add
/// interpolants. Every element of `fine` is overwritten.
fn merge_level<T: Scalar>(
    coarse: &[T],
    cshape: &[usize],
    coeffs: &[T],
    shape: &[usize],
    corr: &[T],
    fine: &mut Vec<T>,
) {
    let active = active_dims(shape);
    let d = shape.len();
    let n = shape[d - 1];
    let last_active = active[d - 1];
    let outer: usize = shape[..d - 1].iter().product();
    fine.clear();
    fine.resize(numel(shape), T::ZERO);
    let mut idx = vec![0usize; d.saturating_sub(1)];
    let mut cflat = 0usize;
    let mut kflat = 0usize;
    for o in 0..outer {
        let others_even = (0..d - 1).all(|k| !active[k] || idx[k] % 2 == 0);
        let line = &mut fine[o * n..(o + 1) * n];
        if !others_even {
            line.copy_from_slice(&coeffs[kflat..kflat + n]);
            kflat += n;
        } else if last_active {
            for (z, slot) in line.iter_mut().enumerate() {
                if z % 2 == 0 {
                    *slot = coarse[cflat] - corr[cflat];
                    cflat += 1;
                } else {
                    *slot = coeffs[kflat];
                    kflat += 1;
                }
            }
        } else {
            for slot in line.iter_mut() {
                *slot = coarse[cflat] - corr[cflat];
                cflat += 1;
            }
        }
        for k in (0..d - 1).rev() {
            idx[k] += 1;
            if idx[k] < shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
    debug_assert_eq!(cflat, numel(cshape));
    debug_assert_eq!(kflat, coeffs.len());
    // coefficient nodes: residual + interpolant of (now final) nodal values
    unresidual_pass(fine, shape);
}

/// One decomposition step on a contiguous level array, in place: `cur` is
/// replaced by the coarse representation, the step's coefficient stream is
/// emitted to `sink` in canonical order, and the coarse shape is returned.
/// Exposed so Algorithm 1's adaptive loop (`compressors::mgard_plus`) can
/// interleave termination checks between levels, and so the fused
/// decompose→quantize path ([`super::fused`]) can plug the quantizer in as
/// the sink.
pub(crate) fn step_decompose_into<T: Scalar, S: CoeffSink<T> + ?Sized>(
    cur: &mut Vec<T>,
    shape: &[usize],
    flags: OptFlags,
    h_level: f64,
    s: &mut DecomposeScratch<T>,
    sink: &mut S,
) -> Vec<usize> {
    residual_pass(cur, shape);
    let cshape = correction(cur, shape, flags, h_level, s);
    let mut coarse = std::mem::take(&mut s.coarse);
    split_level(cur, shape, &s.work_a, &cshape, &mut coarse, sink);
    std::mem::swap(cur, &mut coarse);
    // the old fine array becomes the next step's compaction buffer
    s.coarse = coarse;
    cshape
}

/// Full decomposition with the contiguous engine (fresh scratch).
pub(crate) fn decompose<T: Scalar>(
    hierarchy: &Hierarchy,
    flags: OptFlags,
    padded: Tensor<T>,
    stop_level: usize,
) -> Decomposition<T> {
    let mut scratch = DecomposeScratch::new();
    decompose_scratch(hierarchy, flags, padded, stop_level, &mut scratch)
}

/// Full decomposition with the contiguous engine, reusing `scratch`.
///
/// The per-level coefficient streams escape into the returned
/// [`Decomposition`], so they are freshly allocated; every *internal*
/// buffer (sweeps, corrections, compaction) comes from `scratch`.
pub(crate) fn decompose_scratch<T: Scalar>(
    hierarchy: &Hierarchy,
    flags: OptFlags,
    padded: Tensor<T>,
    stop_level: usize,
    scratch: &mut DecomposeScratch<T>,
) -> Decomposition<T> {
    let ll = hierarchy.nlevels();
    let mut cur = padded.into_vec();
    let mut shape = hierarchy.padded_shape().to_vec();
    // streams collected finest-first, then reversed into level order
    let mut streams_rev: Vec<Vec<T>> = Vec::with_capacity(ll - stop_level);
    for l in ((stop_level + 1)..=ll).rev() {
        let h_level = hierarchy.spacing(l);
        let mut coeffs: Vec<T> = Vec::new();
        shape = step_decompose_into(&mut cur, &shape, flags, h_level, scratch, &mut coeffs);
        streams_rev.push(coeffs);
        debug_assert_eq!(shape, hierarchy.level_shape(l - 1));
    }
    streams_rev.reverse();
    Decomposition {
        hierarchy: hierarchy.clone(),
        start_level: stop_level,
        coarse: Tensor::from_vec(&shape, cur).expect("coarse shape consistent"),
        coeffs: streams_rev,
    }
}

/// Recompose up to `target_level`, returning `Q_{target} u` on its level
/// grid (the full padded array when `target_level == L`). Fresh scratch.
pub(crate) fn recompose<T: Scalar>(
    hierarchy: &Hierarchy,
    flags: OptFlags,
    d: &Decomposition<T>,
    target_level: usize,
) -> Result<Tensor<T>> {
    let mut scratch = DecomposeScratch::new();
    recompose_scratch(hierarchy, flags, d, target_level, &mut scratch)
}

/// Recompose up to `target_level`, reusing `scratch` for every internal
/// buffer (scatter, correction, merge).
pub(crate) fn recompose_scratch<T: Scalar>(
    hierarchy: &Hierarchy,
    flags: OptFlags,
    d: &Decomposition<T>,
    target_level: usize,
    s: &mut DecomposeScratch<T>,
) -> Result<Tensor<T>> {
    let mut cur = d.coarse.data().to_vec();
    let mut shape = d.coarse.shape().to_vec();
    for l in (d.start_level + 1)..=target_level {
        let fine_shape = hierarchy.level_shape(l);
        let coeffs = &d.coeffs[l - d.start_level - 1];
        // correction must be recomputed from the residuals exactly as the
        // decomposition computed it
        let h_level = hierarchy.spacing(l);
        let mut e = std::mem::take(&mut s.level);
        scatter_coeffs_only(coeffs, &fine_shape, &mut e);
        let cshape = correction(&e, &fine_shape, flags, h_level, s);
        debug_assert_eq!(cshape, shape);
        merge_level(&cur, &shape, coeffs, &fine_shape, &s.work_a, &mut e);
        std::mem::swap(&mut cur, &mut e);
        // the old coarse array becomes the next level's scatter buffer
        s.level = e;
        shape = fine_shape;
    }
    Ok(Tensor::from_vec(&shape, cur).expect("recompose shape consistent"))
}

/// Fill `out` with a fine-shaped array holding residuals at coefficient
/// positions and zero at nodal positions (the multilevel component,
/// recomposition side).
fn scatter_coeffs_only<T: Scalar>(coeffs: &[T], shape: &[usize], out: &mut Vec<T>) {
    let active = active_dims(shape);
    let d = shape.len();
    let n = shape[d - 1];
    let last_active = active[d - 1];
    let outer: usize = shape[..d - 1].iter().product();
    out.clear();
    out.resize(numel(shape), T::ZERO);
    let mut idx = vec![0usize; d.saturating_sub(1)];
    let mut k = 0usize;
    for o in 0..outer {
        let others_even = (0..d - 1).all(|q| !active[q] || idx[q] % 2 == 0);
        let line = &mut out[o * n..(o + 1) * n];
        if !others_even {
            line.copy_from_slice(&coeffs[k..k + n]);
            k += n;
        } else if last_active {
            let mut z = 1;
            while z < n {
                line[z] = coeffs[k];
                k += 1;
                z += 2;
            }
        }
        for q in (0..d - 1).rev() {
            idx[q] += 1;
            if idx[q] < shape[q] {
                break;
            }
            idx[q] = 0;
        }
    }
    debug_assert_eq!(k, coeffs.len());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn rand_tensor(shape: &[usize], seed: u64) -> Tensor<f64> {
        let mut rng = Rng::new(seed);
        Tensor::from_fn(shape, |_| rng.uniform_in(-1.0, 1.0))
    }

    fn round_trip(shape: &[usize], flags: OptFlags, seed: u64) {
        let h = Hierarchy::new(shape, None).unwrap();
        let u = rand_tensor(shape, seed);
        let padded = h.pad(&u).unwrap();
        let dec = decompose(&h, flags, padded, 0);
        dec.validate().unwrap();
        let back = recompose(&h, flags, &dec, h.nlevels()).unwrap();
        let back = h.crop(&back).unwrap();
        let err = crate::metrics::linf_error(u.data(), back.data());
        assert!(err < 1e-10, "round trip error {err} for {shape:?} {flags:?}");
    }

    #[test]
    fn round_trip_1d() {
        for flags in [OptFlags::dr(), OptFlags::dr_dlvc(), OptFlags::all()] {
            round_trip(&[17], flags, 1);
            round_trip(&[33], flags, 2);
        }
    }

    #[test]
    fn round_trip_2d() {
        for (i, flags) in [
            OptFlags::dr(),
            OptFlags::dr_dlvc(),
            OptFlags::dr_dlvc_bcc(),
            OptFlags::all(),
        ]
        .into_iter()
        .enumerate()
        {
            round_trip(&[9, 9], flags, 10 + i as u64);
            round_trip(&[17, 9], flags, 20 + i as u64);
        }
    }

    #[test]
    fn round_trip_3d_and_4d() {
        round_trip(&[9, 9, 9], OptFlags::all(), 31);
        round_trip(&[5, 9, 17], OptFlags::all(), 32);
        round_trip(&[5, 5, 5, 5], OptFlags::all(), 33);
    }

    #[test]
    fn round_trip_non_dyadic() {
        round_trip(&[7, 12], OptFlags::all(), 41);
        round_trip(&[6, 10, 11], OptFlags::all(), 42);
    }

    #[test]
    fn all_flag_combos_agree() {
        let shape = [9, 17];
        let h = Hierarchy::new(&shape, None).unwrap();
        let u = rand_tensor(&shape, 55);
        let reference = decompose(&h, OptFlags::all(), h.pad(&u).unwrap(), 0);
        for flags in [OptFlags::dr(), OptFlags::dr_dlvc(), OptFlags::dr_dlvc_bcc()] {
            let other = decompose(&h, flags, h.pad(&u).unwrap(), 0);
            assert_eq!(other.coeffs.len(), reference.coeffs.len());
            for (a, b) in other
                .coarse
                .data()
                .iter()
                .chain(other.coeffs.iter().flatten())
                .zip(reference.coarse.data().iter().chain(reference.coeffs.iter().flatten()))
            {
                assert!((a - b).abs() < 1e-9, "{flags:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn scratch_reuse_is_bit_transparent() {
        // one scratch threaded through decompositions of different shapes
        // and seeds must reproduce the fresh-scratch results bit-for-bit
        let mut s = DecomposeScratch::new();
        for (i, shape) in [&[17usize][..], &[9, 17][..], &[6, 10, 11][..], &[9, 9][..]]
            .iter()
            .enumerate()
        {
            let h = Hierarchy::new(shape, None).unwrap();
            let u = rand_tensor(shape, 900 + i as u64);
            let fresh = decompose(&h, OptFlags::all(), h.pad(&u).unwrap(), 0);
            let reused =
                decompose_scratch(&h, OptFlags::all(), h.pad(&u).unwrap(), 0, &mut s);
            assert_eq!(fresh.coarse.data(), reused.coarse.data(), "{shape:?}");
            assert_eq!(fresh.coeffs, reused.coeffs, "{shape:?}");
            let back_fresh = recompose(&h, OptFlags::all(), &fresh, h.nlevels()).unwrap();
            let back_reused =
                recompose_scratch(&h, OptFlags::all(), &reused, h.nlevels(), &mut s).unwrap();
            assert_eq!(back_fresh.data(), back_reused.data(), "{shape:?}");
        }
    }

    #[test]
    fn panel_width_is_bit_transparent() {
        // width 1 is the per-line oracle; every other width (including one
        // wider than any line count) must reproduce it bit-for-bit, on both
        // decompose and recompose
        for shape in [&[33usize][..], &[17, 9], &[9, 9, 9], &[6, 10, 11]] {
            let h = Hierarchy::new(shape, None).unwrap();
            let u = rand_tensor(shape, 4321);
            let mut s1 = DecomposeScratch::with_panel_width(1);
            let reference =
                decompose_scratch(&h, OptFlags::all(), h.pad(&u).unwrap(), 0, &mut s1);
            let back_ref =
                recompose_scratch(&h, OptFlags::all(), &reference, h.nlevels(), &mut s1)
                    .unwrap();
            for pw in [2usize, 5, 64, 4096] {
                let mut s = DecomposeScratch::with_panel_width(pw);
                let d = decompose_scratch(&h, OptFlags::all(), h.pad(&u).unwrap(), 0, &mut s);
                assert_eq!(reference.coarse.data(), d.coarse.data(), "pw={pw} {shape:?}");
                assert_eq!(reference.coeffs, d.coeffs, "pw={pw} {shape:?}");
                let back =
                    recompose_scratch(&h, OptFlags::all(), &d, h.nlevels(), &mut s).unwrap();
                assert_eq!(back_ref.data(), back.data(), "recompose pw={pw} {shape:?}");
            }
        }
    }

    #[test]
    fn linear_function_has_zero_fine_coefficients() {
        // A multilinear function is reproduced exactly by interpolation, so
        // all multilevel coefficients above the coarsest level must vanish.
        let shape = [9, 9];
        let h = Hierarchy::new(&shape, None).unwrap();
        let u = Tensor::<f64>::from_fn(&shape, |ix| {
            2.0 + 0.5 * ix[0] as f64 - 0.25 * ix[1] as f64
        });
        let dec = decompose(&h, OptFlags::all(), h.pad(&u).unwrap(), 0);
        for (k, stream) in dec.coeffs.iter().enumerate() {
            for &c in stream {
                assert!(c.abs() < 1e-9, "level {} coeff {c}", dec.coeff_level(k));
            }
        }
    }

    #[test]
    fn partial_decompose_stops_at_level() {
        let shape = [17, 17];
        let h = Hierarchy::new(&shape, None).unwrap();
        let u = rand_tensor(&shape, 77);
        let dec = decompose(&h, OptFlags::all(), h.pad(&u).unwrap(), 2);
        assert_eq!(dec.start_level, 2);
        assert_eq!(dec.coarse.shape(), &[9, 9]);
        assert_eq!(dec.coeffs.len(), 1);
        let back = recompose(&h, OptFlags::all(), &dec, h.nlevels()).unwrap();
        let err = crate::metrics::linf_error(h.pad(&u).unwrap().data(), back.data());
        assert!(err < 1e-10);
    }

    #[test]
    fn partial_recompose_is_projection() {
        // recompose_to_level of a full decomposition reproduces the coarse
        // array obtained by a decomposition stopped at that level.
        let shape = [17, 17];
        let h = Hierarchy::new(&shape, None).unwrap();
        let u = rand_tensor(&shape, 88);
        let full = decompose(&h, OptFlags::all(), h.pad(&u).unwrap(), 0);
        let partial = decompose(&h, OptFlags::all(), h.pad(&u).unwrap(), 2);
        let q2 = recompose(&h, OptFlags::all(), &full, 2).unwrap();
        let err = crate::metrics::linf_error(q2.data(), partial.coarse.data());
        assert!(err < 1e-9, "Q_2 mismatch {err}");
    }

    #[test]
    fn residual_pass_zero_on_nodal() {
        let shape = [5, 5];
        let mut data: Vec<f64> = (0..25).map(|i| (i as f64 * 0.7).sin()).collect();
        let orig = data.clone();
        residual_pass(&mut data, &shape);
        // nodal nodes (even, even) unchanged
        for i in (0..5).step_by(2) {
            for j in (0..5).step_by(2) {
                assert_eq!(data[i * 5 + j], orig[i * 5 + j]);
            }
        }
        // edge node (0,1): residual vs horizontal neighbors
        let expect = orig[1] - 0.5 * (orig[0] + orig[2]);
        assert!((data[1] - expect).abs() < 1e-12);
        // cube^2 node (1,1): bilinear corners
        let expect = orig[6] - 0.25 * (orig[0] + orig[2] + orig[10] + orig[12]);
        assert!((data[6] - expect).abs() < 1e-12);
    }
}
