//! The MGARD+ engine: level-centric reordered (contiguous) multilevel
//! decomposition with the §5 optimizations.
//!
//! Instead of striding over the full array with ever-growing strides, each
//! level works on a *contiguous* array holding exactly the current grid
//! `N_l` (the de-interleaving view of §5.1): coefficient computation and
//! correction run cache-coherently, then the nodal nodes are compacted into
//! a new contiguous array for the next level while the coefficient nodes are
//! emitted to the output stream.

use super::sweeps::{load_direct, load_mass_restrict, thomas_solve_fresh, ThomasAux};
use super::{Decomposition, OptFlags};
use crate::error::Result;
use crate::grid::Hierarchy;
use crate::tensor::{numel, Scalar, Tensor};
use std::collections::BTreeMap;

/// Per-decomposition scratch: Thomas factorizations keyed by coarse length
/// (IVER's precomputed auxiliary arrays, shared across levels and dims).
struct AuxCache<T: Scalar> {
    map: BTreeMap<usize, ThomasAux<T>>,
}

impl<T: Scalar> AuxCache<T> {
    fn new() -> Self {
        AuxCache {
            map: BTreeMap::new(),
        }
    }
    fn get(&mut self, n: usize) -> &ThomasAux<T> {
        self.map.entry(n).or_insert_with(|| ThomasAux::new(n, 1.0))
    }
}

/// Which dims halve at this step (size >= 5 still halves; 3 has bottomed out).
fn active_dims(shape: &[usize]) -> Vec<bool> {
    shape.iter().map(|&n| n >= 5).collect()
}

/// In-place coefficient computation: replace every coefficient-node value by
/// its residual against the multilinear interpolant of the nodal nodes.
/// `shape` is the current contiguous level grid.
///
/// The 3-D all-active case (the bulk of every decomposition) is specialized:
/// the generic path pays a per-element parity test and corner-mask loop,
/// while the specialization classifies whole z-lines by the (x, y) parity
/// and runs branch-free stride-2 stencils (§Perf in EXPERIMENTS.md).
pub(crate) fn residual_pass<T: Scalar>(data: &mut [T], shape: &[usize]) {
    if shape.len() == 3 && shape.iter().all(|&n| n >= 5) {
        return residual_pass_3d(data, shape, false);
    }
    residual_pass_generic(data, shape);
}

/// Specialized 3-D residual pass; `inverse` adds the interpolant back.
fn residual_pass_3d<T: Scalar>(data: &mut [T], shape: &[usize], inverse: bool) {
    let (n0, n1, n2) = (shape[0], shape[1], shape[2]);
    let s0 = n1 * n2;
    let half = T::from_f64(0.5);
    let quarter = T::from_f64(0.25);
    let eighth = T::from_f64(0.125);
    // apply `v -= pred` or `v += pred`
    macro_rules! upd {
        ($slot:expr, $pred:expr) => {
            if inverse {
                $slot += $pred;
            } else {
                $slot -= $pred;
            }
        };
    }
    for x in 0..n0 {
        for y in 0..n1 {
            let base = x * s0 + y * n2;
            match (x % 2, y % 2) {
                (0, 0) => {
                    // nodal row: only odd-z (edge) nodes change
                    let mut z = 1;
                    while z < n2 - 1 {
                        let pred = half * (data[base + z - 1] + data[base + z + 1]);
                        upd!(data[base + z], pred);
                        z += 2;
                    }
                }
                (1, 0) | (0, 1) => {
                    // one odd planar dim: neighbors are the two nodal rows
                    let nb = if x % 2 == 1 { s0 } else { n2 };
                    let (lo, hi) = (base - nb, base + nb);
                    // even z: face nodes on the x/y edge
                    let mut z = 0;
                    while z < n2 {
                        let pred = half * (data[lo + z] + data[hi + z]);
                        upd!(data[base + z], pred);
                        z += 2;
                    }
                    // odd z: plane nodes (4 corners)
                    let mut z = 1;
                    while z < n2 - 1 {
                        let pred = quarter
                            * (data[lo + z - 1]
                                + data[lo + z + 1]
                                + data[hi + z - 1]
                                + data[hi + z + 1]);
                        upd!(data[base + z], pred);
                        z += 2;
                    }
                }
                _ => {
                    // x and y both odd: 4 nodal rows at the (x±1, y±1) corners
                    let r00 = base - s0 - n2;
                    let r01 = base - s0 + n2;
                    let r10 = base + s0 - n2;
                    let r11 = base + s0 + n2;
                    let mut z = 0;
                    while z < n2 {
                        let pred = quarter
                            * (data[r00 + z] + data[r01 + z] + data[r10 + z] + data[r11 + z]);
                        upd!(data[base + z], pred);
                        z += 2;
                    }
                    let mut z = 1;
                    while z < n2 - 1 {
                        let pred = eighth
                            * (data[r00 + z - 1]
                                + data[r00 + z + 1]
                                + data[r01 + z - 1]
                                + data[r01 + z + 1]
                                + data[r10 + z - 1]
                                + data[r10 + z + 1]
                                + data[r11 + z - 1]
                                + data[r11 + z + 1]);
                        upd!(data[base + z], pred);
                        z += 2;
                    }
                }
            }
        }
    }
}

fn residual_pass_generic<T: Scalar>(data: &mut [T], shape: &[usize]) {
    let active = active_dims(shape);
    let strides = crate::tensor::strides_for(shape);
    let d = shape.len();
    let mut idx = vec![0usize; d];
    let n = data.len();
    // odd_dims: strides of dims where the index is odd (active only)
    let mut odd: Vec<usize> = Vec::with_capacity(d);
    for flat in 0..n {
        odd.clear();
        for k in 0..d {
            if active[k] && idx[k] % 2 == 1 {
                odd.push(strides[k]);
            }
        }
        let q = odd.len();
        if q > 0 {
            // average of the 2^q corners
            let mut acc = T::ZERO;
            for mask in 0..(1usize << q) {
                let mut off = flat;
                for (b, &s) in odd.iter().enumerate() {
                    if mask & (1 << b) != 0 {
                        off += s;
                    } else {
                        off -= s;
                    }
                }
                acc += data[off];
            }
            let w = T::from_f64(1.0 / (1usize << q) as f64);
            data[flat] -= acc * w;
        }
        // increment multi-index
        for k in (0..d).rev() {
            idx[k] += 1;
            if idx[k] < shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
}

/// Inverse of [`residual_pass`]: add interpolant back to residuals.
fn unresidual_pass<T: Scalar>(data: &mut [T], shape: &[usize]) {
    if shape.len() == 3 && shape.iter().all(|&n| n >= 5) {
        return residual_pass_3d(data, shape, true);
    }
    unresidual_pass_generic(data, shape);
}

fn unresidual_pass_generic<T: Scalar>(data: &mut [T], shape: &[usize]) {
    let active = active_dims(shape);
    let strides = crate::tensor::strides_for(shape);
    let d = shape.len();
    let mut idx = vec![0usize; d];
    let n = data.len();
    let mut odd: Vec<usize> = Vec::with_capacity(d);
    for flat in 0..n {
        odd.clear();
        for k in 0..d {
            if active[k] && idx[k] % 2 == 1 {
                odd.push(strides[k]);
            }
        }
        let q = odd.len();
        if q > 0 {
            let mut acc = T::ZERO;
            for mask in 0..(1usize << q) {
                let mut off = flat;
                for (b, &s) in odd.iter().enumerate() {
                    if mask & (1 << b) != 0 {
                        off += s;
                    } else {
                        off -= s;
                    }
                }
                acc += data[off];
            }
            let w = T::from_f64(1.0 / (1usize << q) as f64);
            data[flat] += acc * w;
        }
        for k in (0..d).rev() {
            idx[k] += 1;
            if idx[k] < shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
}

/// Copy of the level array with nodal positions zeroed: the multilevel
/// component `e = (I - Π) Q_l u`, which is zero on `N_{l-1}`.
fn multilevel_component<T: Scalar>(data: &[T], shape: &[usize]) -> Vec<T> {
    let active = active_dims(shape);
    let d = shape.len();
    let mut e = data.to_vec();
    let mut idx = vec![0usize; d];
    for item in e.iter_mut() {
        let nodal = (0..d).all(|k| !active[k] || idx[k] % 2 == 0);
        if nodal {
            *item = T::ZERO;
        }
        for k in (0..d).rev() {
            idx[k] += 1;
            if idx[k] < shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
    e
}

/// Load sweep along `dim`: consumes an array of `shape`, returns the array
/// with `shape[dim]` halved (load vector contributions along that dim).
fn load_sweep<T: Scalar>(
    input: &[T],
    shape: &[usize],
    dim: usize,
    flags: OptFlags,
    h: f64,
) -> (Vec<T>, Vec<usize>) {
    let n = shape[dim];
    let nc = (n + 1) / 2;
    let outer: usize = shape[..dim].iter().product();
    let inner: usize = shape[dim + 1..].iter().product();
    let mut out_shape = shape.to_vec();
    out_shape[dim] = nc;
    let mut out = vec![T::ZERO; outer * nc * inner];

    if inner == 1 {
        // contiguous lines along the last dim
        let mut scratch = Vec::new();
        for o in 0..outer {
            let line = &input[o * n..(o + 1) * n];
            let dst = &mut out[o * nc..(o + 1) * nc];
            if flags.direct_load {
                load_direct(line, dst, h);
            } else {
                load_mass_restrict(line, dst, h, &mut scratch);
            }
        }
    } else if flags.batched {
        // vectorized direct stencil over the contiguous inner dimension
        let wo = T::from_f64(h / 12.0);
        let wm = T::from_f64(h * 0.5);
        let wc = T::from_f64(h * 5.0 / 6.0);
        let wb = T::from_f64(h * 5.0 / 12.0);
        for o in 0..outer {
            let src = &input[o * n * inner..(o + 1) * n * inner];
            let dst = &mut out[o * nc * inner..(o + 1) * nc * inner];
            // i = 0: wb*c0 + wm*c1 + wo*c2
            {
                let (r0, r1, r2) =
                    (&src[0..inner], &src[inner..2 * inner], &src[2 * inner..3 * inner]);
                let d0 = &mut dst[0..inner];
                for j in 0..inner {
                    d0[j] = wb * r0[j] + wm * r1[j] + wo * r2[j];
                }
            }
            for i in 1..nc - 1 {
                let k = 2 * i;
                let base = (k - 2) * inner;
                let rows = &src[base..base + 5 * inner];
                let d = &mut dst[i * inner..(i + 1) * inner];
                for j in 0..inner {
                    d[j] = wo * rows[j]
                        + wm * rows[inner + j]
                        + wc * rows[2 * inner + j]
                        + wm * rows[3 * inner + j]
                        + wo * rows[4 * inner + j];
                }
            }
            // i = nc-1
            {
                let base = (n - 3) * inner;
                let rows = &src[base..base + 3 * inner];
                let d = &mut dst[(nc - 1) * inner..nc * inner];
                for j in 0..inner {
                    d[j] = wo * rows[j] + wm * rows[inner + j] + wb * rows[2 * inner + j];
                }
            }
        }
    } else {
        // column-at-a-time with strided gather/scatter (the pre-BCC pattern)
        let mut col_in = vec![T::ZERO; n];
        let mut col_out = vec![T::ZERO; nc];
        let mut scratch = Vec::new();
        for o in 0..outer {
            let src_base = o * n * inner;
            let dst_base = o * nc * inner;
            for j in 0..inner {
                for i in 0..n {
                    col_in[i] = input[src_base + i * inner + j];
                }
                if flags.direct_load {
                    load_direct(&col_in, &mut col_out, h);
                } else {
                    load_mass_restrict(&col_in, &mut col_out, h, &mut scratch);
                }
                for i in 0..nc {
                    out[dst_base + i * inner + j] = col_out[i];
                }
            }
        }
    }
    (out, out_shape)
}

/// Tridiagonal mass solve along `dim` (in place).
fn mass_solve<T: Scalar>(
    data: &mut [T],
    shape: &[usize],
    dim: usize,
    flags: OptFlags,
    h: f64,
    aux: &mut AuxCache<T>,
) {
    let n = shape[dim];
    let outer: usize = shape[..dim].iter().product();
    let inner: usize = shape[dim + 1..].iter().product();
    if inner == 1 {
        if flags.reuse {
            let a = aux.get(n).clone();
            for o in 0..outer {
                a.solve(&mut data[o * n..(o + 1) * n]);
            }
        } else {
            for o in 0..outer {
                thomas_solve_fresh(&mut data[o * n..(o + 1) * n], h);
            }
        }
    } else if flags.batched {
        if flags.reuse {
            let a = aux.get(n).clone();
            for o in 0..outer {
                a.solve_batch(&mut data[o * n * inner..(o + 1) * n * inner], inner);
            }
        } else {
            let a = ThomasAux::<T>::new(n, h);
            for o in 0..outer {
                a.solve_batch(&mut data[o * n * inner..(o + 1) * n * inner], inner);
            }
        }
    } else {
        let mut col = vec![T::ZERO; n];
        for o in 0..outer {
            let base = o * n * inner;
            for j in 0..inner {
                for i in 0..n {
                    col[i] = data[base + i * inner + j];
                }
                if flags.reuse {
                    aux.get(n).solve(&mut col);
                } else {
                    thomas_solve_fresh(&mut col, h);
                }
                for i in 0..n {
                    data[base + i * inner + j] = col[i];
                }
            }
        }
    }
}

/// First load sweep fused with the nodal mask: reads the residualized level
/// array directly (even-everywhere entries are implicitly zero) and sweeps
/// along the *last* (contiguous) dimension. This is the IVER elimination of
/// the intermediate multilevel-component array (§5.4): one full-array copy
/// and one full-array write vanish.
fn load_sweep_last_masked<T: Scalar>(
    input: &[T],
    shape: &[usize],
    active: &[bool],
) -> (Vec<T>, Vec<usize>) {
    let d = shape.len();
    let n = shape[d - 1];
    let nc = (n + 1) / 2;
    let outer: usize = shape[..d - 1].iter().product();
    let mut out_shape = shape.to_vec();
    out_shape[d - 1] = nc;
    let mut out = vec![T::ZERO; outer * nc];
    let wo = T::from_f64(1.0 / 12.0);
    let wm = T::from_f64(0.5);
    let wc = T::from_f64(5.0 / 6.0);
    let wb = T::from_f64(5.0 / 12.0);
    let mut idx = vec![0usize; d.saturating_sub(1)];
    for o in 0..outer {
        let others_even = (0..d - 1).all(|k| !active[k] || idx[k] % 2 == 0);
        let line = &input[o * n..(o + 1) * n];
        let dst = &mut out[o * nc..(o + 1) * nc];
        if others_even {
            // nodal (even) entries of e are zero: only the odd taps remain
            dst[0] = wm * line[1];
            for i in 1..nc - 1 {
                let k = 2 * i;
                dst[i] = wm * (line[k - 1] + line[k + 1]);
            }
            dst[nc - 1] = wm * line[n - 2];
        } else {
            // every entry on this line is a coefficient node
            dst[0] = wb * line[0] + wm * line[1] + wo * line[2];
            for i in 1..nc - 1 {
                let k = 2 * i;
                dst[i] = wo * line[k - 2]
                    + wm * line[k - 1]
                    + wc * line[k]
                    + wm * line[k + 1]
                    + wo * line[k + 2];
            }
            dst[nc - 1] = wo * line[n - 3] + wm * line[n - 2] + wb * line[n - 1];
        }
        for k in (0..d - 1).rev() {
            idx[k] += 1;
            if idx[k] < shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
    (out, out_shape)
}

/// Compute the correction `Q_{l-1}(I-Π)Q_l u` from the residualized level
/// array: load sweeps along every active dim, then mass solves.
fn correction<T: Scalar>(
    level_data: &[T],
    shape: &[usize],
    flags: OptFlags,
    h_level: f64,
    aux: &mut AuxCache<T>,
) -> (Vec<T>, Vec<usize>) {
    let active = active_dims(shape);
    let d = shape.len();
    // the h factors cancel against the mass solve; the non-IVER path carries
    // them through both stages like the original implementation
    let h = if flags.reuse { 1.0 } else { h_level };
    let mut work;
    let mut wshape;
    if flags.reuse && flags.direct_load && active[d - 1] {
        // IVER fast path: fused mask + last-dim sweep, no e-copy
        let (w, s) = load_sweep_last_masked(level_data, shape, &active);
        work = w;
        wshape = s;
        for k in 0..d - 1 {
            if active[k] {
                let (w, s) = load_sweep(&work, &wshape, k, flags, h);
                work = w;
                wshape = s;
            }
        }
    } else {
        work = multilevel_component(level_data, shape);
        wshape = shape.to_vec();
        for k in 0..d {
            if active[k] {
                let (w, s) = load_sweep(&work, &wshape, k, flags, h);
                work = w;
                wshape = s;
            }
        }
    }
    for k in 0..d {
        if active[k] {
            mass_solve(&mut work, &wshape, k, flags, h, aux);
        }
    }
    (work, wshape)
}

/// Correction of a given multilevel component in isolation (exposed for the
/// §4.2.2 penalty-factor calibration, which measures the statistical spread
/// of corrections induced by coefficient-node noise).
pub(crate) fn correction_of_component(e: &[f64], shape: &[usize], flags: OptFlags) -> Vec<f64> {
    let mut aux = AuxCache::new();
    let (corr, _) = correction(e, shape, flags, 1.0, &mut aux);
    corr
}

/// De-interleave one level: returns (coarse contiguous array, coefficient
/// stream in canonical order). `corr` is the correction to add to the nodal
/// values.
fn split_level<T: Scalar>(
    data: &[T],
    shape: &[usize],
    corr: &[T],
    cshape: &[usize],
) -> (Vec<T>, Vec<T>) {
    let active = active_dims(shape);
    let d = shape.len();
    let n = shape[d - 1];
    let last_active = active[d - 1];
    let outer: usize = shape[..d - 1].iter().product();
    let mut coarse = vec![T::ZERO; numel(cshape)];
    let mut coeffs = Vec::with_capacity(numel(shape) - numel(cshape));
    let mut idx = vec![0usize; d.saturating_sub(1)];
    let mut cflat = 0usize;
    // line-at-a-time: a whole z-line is coefficient data unless every other
    // active dim is even; the canonical (row-major) order is preserved
    for o in 0..outer {
        let others_even = (0..d - 1).all(|k| !active[k] || idx[k] % 2 == 0);
        let line = &data[o * n..(o + 1) * n];
        if !others_even {
            coeffs.extend_from_slice(line);
        } else if last_active {
            for (z, &v) in line.iter().enumerate() {
                if z % 2 == 0 {
                    coarse[cflat] = v + corr[cflat];
                    cflat += 1;
                } else {
                    coeffs.push(v);
                }
            }
        } else {
            // last dim bottomed out: the whole line is nodal
            for &v in line {
                coarse[cflat] = v + corr[cflat];
                cflat += 1;
            }
        }
        for k in (0..d - 1).rev() {
            idx[k] += 1;
            if idx[k] < shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
    debug_assert_eq!(cflat, numel(cshape));
    (coarse, coeffs)
}

/// Inverse of [`split_level`]: interleave coarse (minus correction) and
/// coefficients back into a fine contiguous array, then add interpolants.
fn merge_level<T: Scalar>(
    coarse: &[T],
    cshape: &[usize],
    coeffs: &[T],
    shape: &[usize],
    corr: &[T],
) -> Vec<T> {
    let active = active_dims(shape);
    let d = shape.len();
    let n = shape[d - 1];
    let last_active = active[d - 1];
    let outer: usize = shape[..d - 1].iter().product();
    let mut fine = vec![T::ZERO; numel(shape)];
    let mut idx = vec![0usize; d.saturating_sub(1)];
    let mut cflat = 0usize;
    let mut kflat = 0usize;
    for o in 0..outer {
        let others_even = (0..d - 1).all(|k| !active[k] || idx[k] % 2 == 0);
        let line = &mut fine[o * n..(o + 1) * n];
        if !others_even {
            line.copy_from_slice(&coeffs[kflat..kflat + n]);
            kflat += n;
        } else if last_active {
            for (z, slot) in line.iter_mut().enumerate() {
                if z % 2 == 0 {
                    *slot = coarse[cflat] - corr[cflat];
                    cflat += 1;
                } else {
                    *slot = coeffs[kflat];
                    kflat += 1;
                }
            }
        } else {
            for slot in line.iter_mut() {
                *slot = coarse[cflat] - corr[cflat];
                cflat += 1;
            }
        }
        for k in (0..d - 1).rev() {
            idx[k] += 1;
            if idx[k] < shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
    debug_assert_eq!(cflat, numel(cshape));
    debug_assert_eq!(kflat, coeffs.len());
    // coefficient nodes: residual + interpolant of (now final) nodal values
    unresidual_pass(&mut fine, shape);
    fine
}

/// One decomposition step on a contiguous level array: returns
/// `(coarse, coarse_shape, coefficient_stream)`. Exposed so Algorithm 1's
/// adaptive loop (compressors::mgard_plus) can interleave termination checks
/// between levels.
pub(crate) fn step_decompose<T: Scalar>(
    cur: Vec<T>,
    shape: &[usize],
    flags: OptFlags,
    h_level: f64,
) -> (Vec<T>, Vec<usize>, Vec<T>) {
    let mut aux = AuxCache::new();
    let mut cur = cur;
    residual_pass(&mut cur, shape);
    let (corr, cshape) = correction(&cur, shape, flags, h_level, &mut aux);
    let (coarse, coeffs) = split_level(&cur, shape, &corr, &cshape);
    (coarse, cshape, coeffs)
}

/// Full decomposition with the contiguous engine.
pub(crate) fn decompose<T: Scalar>(
    hierarchy: &Hierarchy,
    flags: OptFlags,
    padded: Tensor<T>,
    stop_level: usize,
) -> Decomposition<T> {
    let ll = hierarchy.nlevels();
    let mut aux = AuxCache::new();
    let mut cur = padded.into_vec();
    let mut shape = hierarchy.padded_shape().to_vec();
    // streams collected finest-first, then reversed into level order
    let mut streams_rev: Vec<Vec<T>> = Vec::with_capacity(ll - stop_level);
    for l in ((stop_level + 1)..=ll).rev() {
        let h_level = hierarchy.spacing(l);
        residual_pass(&mut cur, &shape);
        let (corr, cshape) = correction(&cur, &shape, flags, h_level, &mut aux);
        let (coarse, coeffs) = split_level(&cur, &shape, &corr, &cshape);
        streams_rev.push(coeffs);
        cur = coarse;
        shape = cshape;
        debug_assert_eq!(shape, hierarchy.level_shape(l - 1));
    }
    streams_rev.reverse();
    Decomposition {
        hierarchy: hierarchy.clone(),
        start_level: stop_level,
        coarse: Tensor::from_vec(&shape, cur).expect("coarse shape consistent"),
        coeffs: streams_rev,
    }
}

/// Recompose up to `target_level`, returning `Q_{target} u` on its level
/// grid (the full padded array when `target_level == L`).
pub(crate) fn recompose<T: Scalar>(
    hierarchy: &Hierarchy,
    flags: OptFlags,
    d: &Decomposition<T>,
    target_level: usize,
) -> Result<Tensor<T>> {
    let mut aux = AuxCache::new();
    let mut cur = d.coarse.data().to_vec();
    let mut shape = d.coarse.shape().to_vec();
    for l in (d.start_level + 1)..=target_level {
        let fine_shape = hierarchy.level_shape(l);
        let coeffs = &d.coeffs[l - d.start_level - 1];
        // correction must be recomputed from the residuals exactly as the
        // decomposition computed it
        let h_level = hierarchy.spacing(l);
        let e_fine = scatter_coeffs_only(coeffs, &fine_shape);
        let (corr, cshape) = correction(&e_fine, &fine_shape, flags, h_level, &mut aux);
        debug_assert_eq!(cshape, shape);
        cur = merge_level(&cur, &shape, coeffs, &fine_shape, &corr);
        shape = fine_shape;
    }
    Ok(Tensor::from_vec(&shape, cur).expect("recompose shape consistent"))
}

/// Build a fine-shaped array holding residuals at coefficient positions and
/// zero at nodal positions (the multilevel component, recomposition side).
fn scatter_coeffs_only<T: Scalar>(coeffs: &[T], shape: &[usize]) -> Vec<T> {
    let active = active_dims(shape);
    let d = shape.len();
    let n = shape[d - 1];
    let last_active = active[d - 1];
    let outer: usize = shape[..d - 1].iter().product();
    let mut out = vec![T::ZERO; numel(shape)];
    let mut idx = vec![0usize; d.saturating_sub(1)];
    let mut k = 0usize;
    for o in 0..outer {
        let others_even = (0..d - 1).all(|q| !active[q] || idx[q] % 2 == 0);
        let line = &mut out[o * n..(o + 1) * n];
        if !others_even {
            line.copy_from_slice(&coeffs[k..k + n]);
            k += n;
        } else if last_active {
            let mut z = 1;
            while z < n {
                line[z] = coeffs[k];
                k += 1;
                z += 2;
            }
        }
        for q in (0..d - 1).rev() {
            idx[q] += 1;
            if idx[q] < shape[q] {
                break;
            }
            idx[q] = 0;
        }
    }
    debug_assert_eq!(k, coeffs.len());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn rand_tensor(shape: &[usize], seed: u64) -> Tensor<f64> {
        let mut rng = Rng::new(seed);
        Tensor::from_fn(shape, |_| rng.uniform_in(-1.0, 1.0))
    }

    fn round_trip(shape: &[usize], flags: OptFlags, seed: u64) {
        let h = Hierarchy::new(shape, None).unwrap();
        let u = rand_tensor(shape, seed);
        let padded = h.pad(&u).unwrap();
        let dec = decompose(&h, flags, padded, 0);
        dec.validate().unwrap();
        let back = recompose(&h, flags, &dec, h.nlevels()).unwrap();
        let back = h.crop(&back).unwrap();
        let err = crate::metrics::linf_error(u.data(), back.data());
        assert!(err < 1e-10, "round trip error {err} for {shape:?} {flags:?}");
    }

    #[test]
    fn round_trip_1d() {
        for flags in [OptFlags::dr(), OptFlags::dr_dlvc(), OptFlags::all()] {
            round_trip(&[17], flags, 1);
            round_trip(&[33], flags, 2);
        }
    }

    #[test]
    fn round_trip_2d() {
        for (i, flags) in [
            OptFlags::dr(),
            OptFlags::dr_dlvc(),
            OptFlags::dr_dlvc_bcc(),
            OptFlags::all(),
        ]
        .into_iter()
        .enumerate()
        {
            round_trip(&[9, 9], flags, 10 + i as u64);
            round_trip(&[17, 9], flags, 20 + i as u64);
        }
    }

    #[test]
    fn round_trip_3d_and_4d() {
        round_trip(&[9, 9, 9], OptFlags::all(), 31);
        round_trip(&[5, 9, 17], OptFlags::all(), 32);
        round_trip(&[5, 5, 5, 5], OptFlags::all(), 33);
    }

    #[test]
    fn round_trip_non_dyadic() {
        round_trip(&[7, 12], OptFlags::all(), 41);
        round_trip(&[6, 10, 11], OptFlags::all(), 42);
    }

    #[test]
    fn all_flag_combos_agree() {
        let shape = [9, 17];
        let h = Hierarchy::new(&shape, None).unwrap();
        let u = rand_tensor(&shape, 55);
        let reference = decompose(&h, OptFlags::all(), h.pad(&u).unwrap(), 0);
        for flags in [OptFlags::dr(), OptFlags::dr_dlvc(), OptFlags::dr_dlvc_bcc()] {
            let other = decompose(&h, flags, h.pad(&u).unwrap(), 0);
            assert_eq!(other.coeffs.len(), reference.coeffs.len());
            for (a, b) in other
                .coarse
                .data()
                .iter()
                .chain(other.coeffs.iter().flatten())
                .zip(reference.coarse.data().iter().chain(reference.coeffs.iter().flatten()))
            {
                assert!((a - b).abs() < 1e-9, "{flags:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn linear_function_has_zero_fine_coefficients() {
        // A multilinear function is reproduced exactly by interpolation, so
        // all multilevel coefficients above the coarsest level must vanish.
        let shape = [9, 9];
        let h = Hierarchy::new(&shape, None).unwrap();
        let u = Tensor::<f64>::from_fn(&shape, |ix| {
            2.0 + 0.5 * ix[0] as f64 - 0.25 * ix[1] as f64
        });
        let dec = decompose(&h, OptFlags::all(), h.pad(&u).unwrap(), 0);
        for (k, stream) in dec.coeffs.iter().enumerate() {
            for &c in stream {
                assert!(c.abs() < 1e-9, "level {} coeff {c}", dec.coeff_level(k));
            }
        }
    }

    #[test]
    fn partial_decompose_stops_at_level() {
        let shape = [17, 17];
        let h = Hierarchy::new(&shape, None).unwrap();
        let u = rand_tensor(&shape, 77);
        let dec = decompose(&h, OptFlags::all(), h.pad(&u).unwrap(), 2);
        assert_eq!(dec.start_level, 2);
        assert_eq!(dec.coarse.shape(), &[9, 9]);
        assert_eq!(dec.coeffs.len(), 1);
        let back = recompose(&h, OptFlags::all(), &dec, h.nlevels()).unwrap();
        let err = crate::metrics::linf_error(h.pad(&u).unwrap().data(), back.data());
        assert!(err < 1e-10);
    }

    #[test]
    fn partial_recompose_is_projection() {
        // recompose_to_level of a full decomposition reproduces the coarse
        // array obtained by a decomposition stopped at that level.
        let shape = [17, 17];
        let h = Hierarchy::new(&shape, None).unwrap();
        let u = rand_tensor(&shape, 88);
        let full = decompose(&h, OptFlags::all(), h.pad(&u).unwrap(), 0);
        let partial = decompose(&h, OptFlags::all(), h.pad(&u).unwrap(), 2);
        let q2 = recompose(&h, OptFlags::all(), &full, 2).unwrap();
        let err = crate::metrics::linf_error(q2.data(), partial.coarse.data());
        assert!(err < 1e-9, "Q_2 mismatch {err}");
    }

    #[test]
    fn residual_pass_zero_on_nodal() {
        let shape = [5, 5];
        let mut data: Vec<f64> = (0..25).map(|i| (i as f64 * 0.7).sin()).collect();
        let orig = data.clone();
        residual_pass(&mut data, &shape);
        // nodal nodes (even, even) unchanged
        for i in (0..5).step_by(2) {
            for j in (0..5).step_by(2) {
                assert_eq!(data[i * 5 + j], orig[i * 5 + j]);
            }
        }
        // edge node (0,1): residual vs horizontal neighbors
        let expect = orig[1] - 0.5 * (orig[0] + orig[2]);
        assert!((data[1] - expect).abs() < 1e-12);
        // cube^2 node (1,1): bilinear corners
        let expect = orig[6] - 0.25 * (orig[0] + orig[2] + orig[10] + orig[12]);
        assert!((data[6] - expect).abs() < 1e-12);
    }
}
