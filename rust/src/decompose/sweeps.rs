//! 1-D primitives of the multilevel transform: load-vector stencils, mass
//! matrices and tridiagonal solves, in both the naive (§2) and optimized
//! (§5.2–§5.4) variants.
//!
//! Geometry: a *fine* line has `2n+1` entries with spacing `h`; its *coarse*
//! line has `n+1` entries with spacing `2h`. The L² projection of a fine
//! piecewise-linear function onto the coarse space is `M⁻¹ f` where `M` is
//! the coarse mass matrix and `f` the coarse load vector.
//!
//! With the common factor `h` kept (the un-optimized formulation):
//!   * load (Lemma 1): `f_i = h·(1/12·c_{2i-2} + 1/2·c_{2i-1} + 5/6·c_{2i}
//!     + 1/2·c_{2i+1} + 1/12·c_{2i+2})`, boundary rows
//!     `f_0 = h·(5/12·c_0 + 1/2·c_1 + 1/12·c_2)` (mirrored at the end);
//!   * mass: `tridiag(1/3, 4/3, 1/3)·h` with `2/3·h` corners.
//!
//! IVER (§5.4) cancels `h` between the two, so the optimized path uses the
//! `h`-free stencils and a precomputed Thomas factorization per line length.

use crate::tensor::Scalar;

/// Interior load stencil weights (c_{2i-2}, c_{2i-1}, c_{2i}, c_{2i+1}, c_{2i+2}).
const W_OUT: f64 = 1.0 / 12.0;
const W_MID: f64 = 0.5;
const W_CTR: f64 = 5.0 / 6.0;
/// Boundary diagonal weight (exact element integral; see module docs).
const W_CTR_B: f64 = 5.0 / 12.0;

/// Direct load-vector computation (DLVC, Lemma 1 generalized): maps a fine
/// line `c` of length `2n+1` to a coarse load `f` of length `n+1`.
/// `h` multiplies every entry (pass 1.0 for the h-free optimized path).
pub fn load_direct<T: Scalar>(c: &[T], f: &mut [T], h: f64) {
    let m = c.len();
    debug_assert!(m >= 3 && m % 2 == 1);
    let n = m / 2;
    debug_assert_eq!(f.len(), n + 1);
    let wo = T::from_f64(W_OUT * h);
    let wm = T::from_f64(W_MID * h);
    let wc = T::from_f64(W_CTR * h);
    let wb = T::from_f64(W_CTR_B * h);
    // i = 0
    f[0] = wb * c[0] + wm * c[1] + wo * c[2];
    // interior
    for i in 1..n {
        let k = 2 * i;
        f[i] = wo * c[k - 2] + wm * c[k - 1] + wc * c[k] + wm * c[k + 1] + wo * c[k + 2];
    }
    // i = n
    f[n] = wo * c[m - 3] + wm * c[m - 2] + wb * c[m - 1];
}

/// Naive load-vector computation as in the original multilevel method:
/// fine-grained mass-matrix multiplication followed by a restriction
/// transform. Mathematically identical to [`load_direct`]; kept for the
/// Fig. 6 baseline.
pub fn load_mass_restrict<T: Scalar>(c: &[T], f: &mut [T], h: f64, scratch: &mut Vec<T>) {
    let m = c.len();
    debug_assert!(m >= 3 && m % 2 == 1);
    let n = m / 2;
    debug_assert_eq!(f.len(), n + 1);
    scratch.clear();
    scratch.resize(m, T::ZERO);
    // fine mass multiply: interior rows h(1/6, 2/3, 1/6); boundary h(1/3, 1/6)
    let d_in = T::from_f64(2.0 / 3.0 * h);
    let d_bd = T::from_f64(1.0 / 3.0 * h);
    let off = T::from_f64(1.0 / 6.0 * h);
    scratch[0] = d_bd * c[0] + off * c[1];
    for j in 1..m - 1 {
        scratch[j] = off * c[j - 1] + d_in * c[j] + off * c[j + 1];
    }
    scratch[m - 1] = off * c[m - 2] + d_bd * c[m - 1];
    // restriction: f_i = w_{2i} + (w_{2i-1} + w_{2i+1})/2
    let half = T::from_f64(0.5);
    f[0] = scratch[0] + half * scratch[1];
    for i in 1..n {
        let k = 2 * i;
        f[i] = scratch[k] + half * (scratch[k - 1] + scratch[k + 1]);
    }
    f[n] = scratch[m - 1] + half * scratch[m - 2];
}

/// Reference load vector by direct element-by-element assembly of
/// `∫ e·φ_i` over fine elements (test oracle for the two fast versions).
#[cfg(test)]
pub fn load_assembled(c: &[f64], h: f64) -> Vec<f64> {
    let m = c.len();
    let n = m / 2;
    let mut f = vec![0.0; n + 1];
    // coarse hat φ_i is supported on fine elements [2i-2, 2i) and [2i, 2i+2).
    // On each fine element [j, j+1], e(t) = c_j(1-t) + c_{j+1} t and
    // φ_i(t) is linear between its nodal values at j and j+1.
    for j in 0..m - 1 {
        // φ_i values at fine nodes j and j+1 for every coarse i
        for i in 0..n + 1 {
            let k = 2 * i as isize;
            let phi = |x: isize| -> f64 {
                let d = (x - k).abs() as f64;
                (1.0 - d / 2.0).max(0.0)
            };
            let (pa, pb) = (phi(j as isize), phi(j as isize + 1));
            if pa == 0.0 && pb == 0.0 {
                continue;
            }
            // ∫_0^1 (c_a(1-t)+c_b t)(pa(1-t)+pb t) h dt
            let (ca, cb) = (c[j], c[j + 1]);
            f[i] += h * (ca * pa / 3.0 + (ca * pb + cb * pa) / 6.0 + cb * pb / 3.0);
        }
    }
    f
}

/// Precomputed Thomas factorization of the coarse mass matrix
/// `tridiag(e, d, e)` with `d = 4/3` interior, `2/3` corners, `e = 1/3`
/// (all scaled by `h`). Reused across every line of a sweep (IVER).
#[derive(Clone, Debug)]
pub struct ThomasAux<T: Scalar> {
    /// `c'_i = e / denom_i` forward-sweep coefficients.
    cp: Vec<T>,
    /// `1 / denom_i` reciprocal pivots.
    inv_denom: Vec<T>,
    /// Off-diagonal entry (scaled by h).
    e: T,
}

impl<T: Scalar> ThomasAux<T> {
    /// Factor the coarse mass matrix for a line of `n` coarse nodes.
    pub fn new(n: usize, h: f64) -> Self {
        debug_assert!(n >= 2);
        let e = 1.0 / 3.0 * h;
        let d_in = 4.0 / 3.0 * h;
        let d_bd = 2.0 / 3.0 * h;
        let mut cp = vec![T::ZERO; n];
        let mut inv_denom = vec![T::ZERO; n];
        let mut denom = d_bd;
        inv_denom[0] = T::from_f64(1.0 / denom);
        cp[0] = T::from_f64(e / denom);
        for i in 1..n {
            let d = if i == n - 1 { d_bd } else { d_in };
            denom = d - e * (e / denom);
            // recompute cp[i-1]-consistent denom chain in f64 for stability
            inv_denom[i] = T::from_f64(1.0 / denom);
            cp[i] = T::from_f64(e / denom);
        }
        ThomasAux {
            cp,
            inv_denom,
            e: T::from_f64(e),
        }
    }

    /// Number of coarse nodes this factorization covers.
    pub fn len(&self) -> usize {
        self.cp.len()
    }

    /// Whether the factorization is empty (never true in practice).
    pub fn is_empty(&self) -> bool {
        self.cp.is_empty()
    }

    /// Solve `M x = f` in place on a contiguous line.
    pub fn solve(&self, f: &mut [T]) {
        let n = f.len();
        debug_assert_eq!(n, self.cp.len());
        // forward
        f[0] = f[0] * self.inv_denom[0];
        for i in 1..n {
            f[i] = (f[i] - self.e * f[i - 1]) * self.inv_denom[i];
        }
        // backward
        for i in (0..n - 1).rev() {
            let t = f[i + 1];
            f[i] = f[i] - self.cp[i] * t;
        }
    }

    /// Solve `M x = f` for `batch` interleaved lines stored as
    /// `f[i * batch + b]` (row i of every line contiguous): the BCC layout.
    /// The inner loops run over contiguous memory.
    pub fn solve_batch(&self, f: &mut [T], batch: usize) {
        let n = self.cp.len();
        debug_assert_eq!(f.len(), n * batch);
        // forward
        for b in 0..batch {
            f[b] = f[b] * self.inv_denom[0];
        }
        for i in 1..n {
            let (prev, cur) = f.split_at_mut(i * batch);
            let prev = &prev[(i - 1) * batch..];
            let cur = &mut cur[..batch];
            let inv = self.inv_denom[i];
            let e = self.e;
            for b in 0..batch {
                cur[b] = (cur[b] - e * prev[b]) * inv;
            }
        }
        // backward
        for i in (0..n - 1).rev() {
            let (cur, next) = f.split_at_mut((i + 1) * batch);
            let cur = &mut cur[i * batch..];
            let next = &next[..batch];
            let cp = self.cp[i];
            for b in 0..batch {
                cur[b] = cur[b] - cp * next[b];
            }
        }
    }
}

/// Plain Thomas solve building its factorization on the fly (the non-IVER
/// path, recomputing auxiliary arrays for every line as the original method
/// does).
pub fn thomas_solve_fresh<T: Scalar>(f: &mut [T], h: f64) {
    let aux = ThomasAux::<T>::new(f.len(), h);
    aux.solve(f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn rand_line(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
    }

    #[test]
    fn direct_load_matches_assembly() {
        for &m in &[5usize, 9, 17, 33] {
            let c = rand_line(m, m as u64);
            let oracle = load_assembled(&c, 1.0);
            let mut fast = vec![0.0; m / 2 + 1];
            load_direct(&c, &mut fast, 1.0);
            for (a, b) in fast.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-12, "m={m}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn mass_restrict_matches_direct() {
        for &m in &[5usize, 9, 33, 65] {
            let c = rand_line(m, 7 + m as u64);
            let mut a = vec![0.0; m / 2 + 1];
            let mut b = vec![0.0; m / 2 + 1];
            let mut scratch = Vec::new();
            load_direct(&c, &mut a, 2.5);
            load_mass_restrict(&c, &mut b, 2.5, &mut scratch);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-12, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn h_scaling_is_linear() {
        let c = rand_line(9, 3);
        let mut f1 = vec![0.0; 5];
        let mut f2 = vec![0.0; 5];
        load_direct(&c, &mut f1, 1.0);
        load_direct(&c, &mut f2, 4.0);
        for (a, b) in f1.iter().zip(&f2) {
            assert!((a * 4.0 - b).abs() < 1e-12);
        }
    }

    /// Multiply the coarse mass matrix by x (dense reference).
    fn mass_mul(x: &[f64], h: f64) -> Vec<f64> {
        let n = x.len();
        let e = h / 3.0;
        let d_in = 4.0 * h / 3.0;
        let d_bd = 2.0 * h / 3.0;
        (0..n)
            .map(|i| {
                let d = if i == 0 || i == n - 1 { d_bd } else { d_in };
                let mut v = d * x[i];
                if i > 0 {
                    v += e * x[i - 1];
                }
                if i + 1 < n {
                    v += e * x[i + 1];
                }
                v
            })
            .collect()
    }

    #[test]
    fn thomas_inverts_mass() {
        for &n in &[2usize, 3, 5, 9, 17] {
            for &h in &[1.0, 2.0] {
                let x = rand_line(n, n as u64 * 31 + h as u64);
                let mut f = mass_mul(&x, h);
                let aux = ThomasAux::<f64>::new(n, h);
                aux.solve(&mut f);
                for (a, b) in f.iter().zip(&x) {
                    assert!((a - b).abs() < 1e-10, "n={n} h={h}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn fresh_equals_precomputed() {
        let x = rand_line(9, 5);
        let mut a = x.clone();
        let mut b = x.clone();
        thomas_solve_fresh(&mut a, 3.0);
        ThomasAux::<f64>::new(9, 3.0).solve(&mut b);
        for (p, q) in a.iter().zip(&b) {
            assert!((p - q).abs() < 1e-14);
        }
    }

    #[test]
    fn batch_solve_matches_scalar() {
        let n = 9;
        let batch = 7;
        let aux = ThomasAux::<f64>::new(n, 1.0);
        // build interleaved batch from independent lines
        let lines: Vec<Vec<f64>> = (0..batch).map(|b| rand_line(n, 100 + b as u64)).collect();
        let mut inter = vec![0.0; n * batch];
        for (b, line) in lines.iter().enumerate() {
            for i in 0..n {
                inter[i * batch + b] = line[i];
            }
        }
        aux.solve_batch(&mut inter, batch);
        for (b, line) in lines.iter().enumerate() {
            let mut expect = line.clone();
            aux.solve(&mut expect);
            for i in 0..n {
                assert!(
                    (inter[i * batch + b] - expect[i]).abs() < 1e-12,
                    "line {b} row {i}"
                );
            }
        }
    }

    #[test]
    fn f32_precision_reasonable() {
        let n = 33;
        let x64 = rand_line(n, 9);
        let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
        let mut f64v = mass_mul(&x64, 1.0);
        let mut f32v: Vec<f32> = f64v.iter().map(|&v| v as f32).collect();
        ThomasAux::<f64>::new(n, 1.0).solve(&mut f64v);
        ThomasAux::<f32>::new(n, 1.0).solve(&mut f32v);
        for (a, b) in f32v.iter().zip(&f64v) {
            assert!((*a as f64 - b).abs() < 1e-4);
        }
    }
}
