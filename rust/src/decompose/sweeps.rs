//! 1-D primitives of the multilevel transform: load-vector stencils, mass
//! matrices and tridiagonal solves, in both the naive (§2) and optimized
//! (§5.2–§5.4) variants.
//!
//! Geometry: a *fine* line has `2n+1` entries with spacing `h`; its *coarse*
//! line has `n+1` entries with spacing `2h`. The L² projection of a fine
//! piecewise-linear function onto the coarse space is `M⁻¹ f` where `M` is
//! the coarse mass matrix and `f` the coarse load vector.
//!
//! With the common factor `h` kept (the un-optimized formulation):
//!   * load (Lemma 1): `f_i = h·(1/12·c_{2i-2} + 1/2·c_{2i-1} + 5/6·c_{2i}
//!     + 1/2·c_{2i+1} + 1/12·c_{2i+2})`, boundary rows
//!     `f_0 = h·(5/12·c_0 + 1/2·c_1 + 1/12·c_2)` (mirrored at the end);
//!   * mass: `tridiag(1/3, 4/3, 1/3)·h` with `2/3·h` corners.
//!
//! IVER (§5.4) cancels `h` between the two, so the optimized path uses the
//! `h`-free stencils and a precomputed Thomas factorization per line length.

use crate::tensor::Scalar;

/// Interior load stencil weights (c_{2i-2}, c_{2i-1}, c_{2i}, c_{2i+1}, c_{2i+2}).
const W_OUT: f64 = 1.0 / 12.0;
const W_MID: f64 = 0.5;
const W_CTR: f64 = 5.0 / 6.0;
/// Boundary diagonal weight (exact element integral; see module docs).
const W_CTR_B: f64 = 5.0 / 12.0;

/// Direct load-vector computation (DLVC, Lemma 1 generalized): maps a fine
/// line `c` of length `2n+1` to a coarse load `f` of length `n+1`.
/// `h` multiplies every entry (pass 1.0 for the h-free optimized path).
pub fn load_direct<T: Scalar>(c: &[T], f: &mut [T], h: f64) {
    let m = c.len();
    debug_assert!(m >= 3 && m % 2 == 1);
    let n = m / 2;
    debug_assert_eq!(f.len(), n + 1);
    let wo = T::from_f64(W_OUT * h);
    let wm = T::from_f64(W_MID * h);
    let wc = T::from_f64(W_CTR * h);
    let wb = T::from_f64(W_CTR_B * h);
    // i = 0
    f[0] = wb * c[0] + wm * c[1] + wo * c[2];
    // interior
    for i in 1..n {
        let k = 2 * i;
        f[i] = wo * c[k - 2] + wm * c[k - 1] + wc * c[k] + wm * c[k + 1] + wo * c[k + 2];
    }
    // i = n
    f[n] = wo * c[m - 3] + wm * c[m - 2] + wb * c[m - 1];
}

/// Panel variant of [`load_direct`]: `bw` lines interleaved lane-wise.
///
/// `c` holds `2n+1` rows of `bw` lanes (`c[i * bw + b]` = entry `i` of lane
/// `b`), `f` receives `n+1` rows in the same layout. Every lane undergoes
/// **exactly** the operation sequence of [`load_direct`] — same weights,
/// same association order — so the panel kernel is bit-identical to the
/// per-line kernel while the inner loops run over `bw` contiguous lanes
/// (auto-vectorizable, no per-line bounds checks).
pub fn load_direct_panel<T: Scalar>(c: &[T], f: &mut [T], bw: usize, h: f64) {
    debug_assert!(bw >= 1);
    let m = c.len() / bw;
    debug_assert_eq!(c.len(), m * bw);
    debug_assert!(m >= 3 && m % 2 == 1);
    let n = m / 2;
    debug_assert_eq!(f.len(), (n + 1) * bw);
    let wo = T::from_f64(W_OUT * h);
    let wm = T::from_f64(W_MID * h);
    let wc = T::from_f64(W_CTR * h);
    let wb = T::from_f64(W_CTR_B * h);
    // i = 0
    {
        let (r0, r1, r2) = (&c[..bw], &c[bw..2 * bw], &c[2 * bw..3 * bw]);
        let d0 = &mut f[..bw];
        for b in 0..bw {
            d0[b] = wb * r0[b] + wm * r1[b] + wo * r2[b];
        }
    }
    // interior
    for i in 1..n {
        let k = 2 * i;
        let rows = &c[(k - 2) * bw..(k + 3) * bw];
        let d = &mut f[i * bw..(i + 1) * bw];
        for b in 0..bw {
            d[b] = wo * rows[b]
                + wm * rows[bw + b]
                + wc * rows[2 * bw + b]
                + wm * rows[3 * bw + b]
                + wo * rows[4 * bw + b];
        }
    }
    // i = n
    {
        let rows = &c[(m - 3) * bw..m * bw];
        let d = &mut f[n * bw..(n + 1) * bw];
        for b in 0..bw {
            d[b] = wo * rows[b] + wm * rows[bw + b] + wb * rows[2 * bw + b];
        }
    }
}

/// Naive load-vector computation as in the original multilevel method:
/// fine-grained mass-matrix multiplication followed by a restriction
/// transform. Mathematically identical to [`load_direct`]; kept for the
/// Fig. 6 baseline.
pub fn load_mass_restrict<T: Scalar>(c: &[T], f: &mut [T], h: f64, scratch: &mut Vec<T>) {
    let m = c.len();
    debug_assert!(m >= 3 && m % 2 == 1);
    let n = m / 2;
    debug_assert_eq!(f.len(), n + 1);
    scratch.clear();
    scratch.resize(m, T::ZERO);
    // fine mass multiply: interior rows h(1/6, 2/3, 1/6); boundary h(1/3, 1/6)
    let d_in = T::from_f64(2.0 / 3.0 * h);
    let d_bd = T::from_f64(1.0 / 3.0 * h);
    let off = T::from_f64(1.0 / 6.0 * h);
    scratch[0] = d_bd * c[0] + off * c[1];
    for j in 1..m - 1 {
        scratch[j] = off * c[j - 1] + d_in * c[j] + off * c[j + 1];
    }
    scratch[m - 1] = off * c[m - 2] + d_bd * c[m - 1];
    // restriction: f_i = w_{2i} + (w_{2i-1} + w_{2i+1})/2
    let half = T::from_f64(0.5);
    f[0] = scratch[0] + half * scratch[1];
    for i in 1..n {
        let k = 2 * i;
        f[i] = scratch[k] + half * (scratch[k - 1] + scratch[k + 1]);
    }
    f[n] = scratch[m - 1] + half * scratch[m - 2];
}

/// Panel variant of [`load_mass_restrict`]: `bw` lane-interleaved lines,
/// same layout as [`load_direct_panel`], with the fine mass multiply kept
/// in a caller-provided `w` scratch (`m * bw` lanes). Per-lane arithmetic
/// is exactly that of [`load_mass_restrict`], so the two are bit-identical.
pub fn load_mass_restrict_panel<T: Scalar>(
    c: &[T],
    f: &mut [T],
    bw: usize,
    h: f64,
    w: &mut Vec<T>,
) {
    debug_assert!(bw >= 1);
    let m = c.len() / bw;
    debug_assert_eq!(c.len(), m * bw);
    debug_assert!(m >= 3 && m % 2 == 1);
    let n = m / 2;
    debug_assert_eq!(f.len(), (n + 1) * bw);
    w.clear();
    w.resize(m * bw, T::ZERO);
    let d_in = T::from_f64(2.0 / 3.0 * h);
    let d_bd = T::from_f64(1.0 / 3.0 * h);
    let off = T::from_f64(1.0 / 6.0 * h);
    for b in 0..bw {
        w[b] = d_bd * c[b] + off * c[bw + b];
    }
    for j in 1..m - 1 {
        let rows = &c[(j - 1) * bw..(j + 2) * bw];
        let wj = &mut w[j * bw..(j + 1) * bw];
        for b in 0..bw {
            wj[b] = off * rows[b] + d_in * rows[bw + b] + off * rows[2 * bw + b];
        }
    }
    for b in 0..bw {
        w[(m - 1) * bw + b] = off * c[(m - 2) * bw + b] + d_bd * c[(m - 1) * bw + b];
    }
    let half = T::from_f64(0.5);
    for b in 0..bw {
        f[b] = w[b] + half * w[bw + b];
    }
    for i in 1..n {
        let k = 2 * i;
        let (wk, fk) = (k * bw, i * bw);
        for b in 0..bw {
            f[fk + b] = w[wk + b] + half * (w[wk - bw + b] + w[wk + bw + b]);
        }
    }
    for b in 0..bw {
        f[n * bw + b] = w[(m - 1) * bw + b] + half * w[(m - 2) * bw + b];
    }
}

/// Reference load vector by direct element-by-element assembly of
/// `∫ e·φ_i` over fine elements (test oracle for the two fast versions).
#[cfg(test)]
pub fn load_assembled(c: &[f64], h: f64) -> Vec<f64> {
    let m = c.len();
    let n = m / 2;
    let mut f = vec![0.0; n + 1];
    // coarse hat φ_i is supported on fine elements [2i-2, 2i) and [2i, 2i+2).
    // On each fine element [j, j+1], e(t) = c_j(1-t) + c_{j+1} t and
    // φ_i(t) is linear between its nodal values at j and j+1.
    for j in 0..m - 1 {
        // φ_i values at fine nodes j and j+1 for every coarse i
        for i in 0..n + 1 {
            let k = 2 * i as isize;
            let phi = |x: isize| -> f64 {
                let d = (x - k).abs() as f64;
                (1.0 - d / 2.0).max(0.0)
            };
            let (pa, pb) = (phi(j as isize), phi(j as isize + 1));
            if pa == 0.0 && pb == 0.0 {
                continue;
            }
            // ∫_0^1 (c_a(1-t)+c_b t)(pa(1-t)+pb t) h dt
            let (ca, cb) = (c[j], c[j + 1]);
            f[i] += h * (ca * pa / 3.0 + (ca * pb + cb * pa) / 6.0 + cb * pb / 3.0);
        }
    }
    f
}

/// Precomputed Thomas factorization of the coarse mass matrix
/// `tridiag(e, d, e)` with `d = 4/3` interior, `2/3` corners, `e = 1/3`
/// (all scaled by `h`). Reused across every line of a sweep (IVER).
#[derive(Clone, Debug)]
pub struct ThomasAux<T: Scalar> {
    /// `c'_i = e / denom_i` forward-sweep coefficients.
    cp: Vec<T>,
    /// `1 / denom_i` reciprocal pivots.
    inv_denom: Vec<T>,
    /// Off-diagonal entry (scaled by h).
    e: T,
}

impl<T: Scalar> ThomasAux<T> {
    /// Factor the coarse mass matrix for a line of `n` coarse nodes.
    pub fn new(n: usize, h: f64) -> Self {
        debug_assert!(n >= 2);
        let e = 1.0 / 3.0 * h;
        let d_in = 4.0 / 3.0 * h;
        let d_bd = 2.0 / 3.0 * h;
        let mut cp = vec![T::ZERO; n];
        let mut inv_denom = vec![T::ZERO; n];
        let mut denom = d_bd;
        inv_denom[0] = T::from_f64(1.0 / denom);
        cp[0] = T::from_f64(e / denom);
        for i in 1..n {
            let d = if i == n - 1 { d_bd } else { d_in };
            denom = d - e * (e / denom);
            // recompute cp[i-1]-consistent denom chain in f64 for stability
            inv_denom[i] = T::from_f64(1.0 / denom);
            cp[i] = T::from_f64(e / denom);
        }
        ThomasAux {
            cp,
            inv_denom,
            e: T::from_f64(e),
        }
    }

    /// Number of coarse nodes this factorization covers.
    pub fn len(&self) -> usize {
        self.cp.len()
    }

    /// Whether the factorization is empty (never true in practice).
    pub fn is_empty(&self) -> bool {
        self.cp.is_empty()
    }

    /// Solve `M x = f` in place on a contiguous line.
    pub fn solve(&self, f: &mut [T]) {
        let n = f.len();
        debug_assert_eq!(n, self.cp.len());
        // forward
        f[0] = f[0] * self.inv_denom[0];
        for i in 1..n {
            f[i] = (f[i] - self.e * f[i - 1]) * self.inv_denom[i];
        }
        // backward
        for i in (0..n - 1).rev() {
            let t = f[i + 1];
            f[i] = f[i] - self.cp[i] * t;
        }
    }

    /// Solve `M x = f` for `batch` interleaved lines stored as
    /// `f[i * batch + b]` (row i of every line contiguous): the BCC layout.
    /// The inner loops run over contiguous memory.
    pub fn solve_batch(&self, f: &mut [T], batch: usize) {
        let n = self.cp.len();
        debug_assert_eq!(f.len(), n * batch);
        // forward
        for b in 0..batch {
            f[b] = f[b] * self.inv_denom[0];
        }
        for i in 1..n {
            let (prev, cur) = f.split_at_mut(i * batch);
            let prev = &prev[(i - 1) * batch..];
            let cur = &mut cur[..batch];
            let inv = self.inv_denom[i];
            let e = self.e;
            for b in 0..batch {
                cur[b] = (cur[b] - e * prev[b]) * inv;
            }
        }
        // backward
        for i in (0..n - 1).rev() {
            let (cur, next) = f.split_at_mut((i + 1) * batch);
            let cur = &mut cur[i * batch..];
            let next = &next[..batch];
            let cp = self.cp[i];
            for b in 0..batch {
                cur[b] = cur[b] - cp * next[b];
            }
        }
    }

    /// Cache-blocked variant of [`solve_batch`](Self::solve_batch): the
    /// `batch` interleaved lines are processed in column panels of at most
    /// `panel` lanes, so one forward+backward pass keeps a working set of
    /// `O(panel)` elements per row instead of `O(batch)` — for wide inner
    /// dimensions the row pair under update stays cache-resident. Every
    /// element undergoes exactly the operation sequence of
    /// [`solve_batch`](Self::solve_batch) (and therefore of
    /// [`solve`](Self::solve)), so all three are bit-identical; `panel == 0`
    /// or `panel >= batch` degenerates to one unblocked pass.
    pub fn solve_batch_blocked(&self, f: &mut [T], batch: usize, panel: usize) {
        if panel == 0 || panel >= batch {
            return self.solve_batch(f, batch);
        }
        let n = self.cp.len();
        debug_assert_eq!(f.len(), n * batch);
        let mut p0 = 0;
        while p0 < batch {
            let w = panel.min(batch - p0);
            // forward
            {
                let inv0 = self.inv_denom[0];
                let row0 = &mut f[p0..p0 + w];
                for b in 0..w {
                    row0[b] = row0[b] * inv0;
                }
            }
            for i in 1..n {
                let (prev, cur) = f.split_at_mut(i * batch);
                let prev = &prev[(i - 1) * batch + p0..(i - 1) * batch + p0 + w];
                let cur = &mut cur[p0..p0 + w];
                let inv = self.inv_denom[i];
                let e = self.e;
                for b in 0..w {
                    cur[b] = (cur[b] - e * prev[b]) * inv;
                }
            }
            // backward
            for i in (0..n - 1).rev() {
                let (cur, next) = f.split_at_mut((i + 1) * batch);
                let cur = &mut cur[i * batch + p0..i * batch + p0 + w];
                let next = &next[p0..p0 + w];
                let cp = self.cp[i];
                for b in 0..w {
                    cur[b] = cur[b] - cp * next[b];
                }
            }
            p0 += w;
        }
    }
}

/// Transpose-gather tile for batching contiguous lines through the panel
/// kernels ([`load_direct_panel`], [`load_mass_restrict_panel`],
/// [`ThomasAux::solve_batch`]).
///
/// For a sweep whose lines are already stride-1 (the last dimension), a
/// panel of `bw` consecutive lines is transposed on load into the
/// lane-interleaved layout `tile[i * bw + b]` (row `i` of lane `b`), the
/// panel kernel runs with stride-1 inner loops over the `bw` lanes, and
/// the result is transposed back on store.
///
/// # Invariants
///
/// * The tile buffers carry **no state between panels or calls** — every
///   `gather` fully overwrites the region the subsequent kernel reads, so
///   reuse is value-transparent (pinned by the differential suite in
///   `rust/tests/panel_differential.rs`).
/// * Buffers grow to the high-water mark `max_line_len × panel_width` and
///   are never shrunk, preserving the per-worker O(1)-allocation
///   steady-state invariant of `DecomposeScratch`.
/// * Like the rest of the scratch, a `LinePanel` is single-threaded state.
#[derive(Debug)]
pub struct LinePanel<T: Scalar> {
    /// Lane-interleaved input tile (also the in-place solve tile).
    pub(crate) tile_in: Vec<T>,
    /// Lane-interleaved output tile of the load kernels.
    pub(crate) tile_out: Vec<T>,
    /// Fine mass-multiply scratch of [`load_mass_restrict_panel`].
    pub(crate) mass: Vec<T>,
}

impl<T: Scalar> LinePanel<T> {
    /// Fresh, empty tile.
    pub fn new() -> Self {
        LinePanel {
            tile_in: Vec::new(),
            tile_out: Vec::new(),
            mass: Vec::new(),
        }
    }

    /// Transpose-gather `bw` consecutive lines of length `n`, starting at
    /// line `o0`, from `src` (lines contiguous at stride `n`) into
    /// `tile_in`'s lane-interleaved layout.
    pub(crate) fn gather(&mut self, src: &[T], o0: usize, n: usize, bw: usize) {
        self.tile_in.clear();
        self.tile_in.resize(n * bw, T::ZERO);
        for b in 0..bw {
            let line = &src[(o0 + b) * n..(o0 + b + 1) * n];
            for (i, &v) in line.iter().enumerate() {
                self.tile_in[i * bw + b] = v;
            }
        }
    }

    /// Size `tile_out` for `rows` rows of `bw` lanes (contents are fully
    /// overwritten by the panel kernel).
    pub(crate) fn ensure_out(&mut self, rows: usize, bw: usize) {
        self.tile_out.clear();
        self.tile_out.resize(rows * bw, T::ZERO);
    }

    /// Transpose-scatter `tile_out` (rows × `bw` lanes) back to `bw`
    /// consecutive lines of length `rows` starting at line `o0` of `dst`.
    pub(crate) fn scatter_out(&self, dst: &mut [T], o0: usize, rows: usize, bw: usize) {
        for b in 0..bw {
            let line = &mut dst[(o0 + b) * rows..(o0 + b + 1) * rows];
            for (i, slot) in line.iter_mut().enumerate() {
                *slot = self.tile_out[i * bw + b];
            }
        }
    }

    /// Transpose-scatter `tile_in` (after an in-place solve) back to `bw`
    /// consecutive lines of length `rows` starting at line `o0` of `dst`.
    pub(crate) fn scatter_in(&self, dst: &mut [T], o0: usize, rows: usize, bw: usize) {
        for b in 0..bw {
            let line = &mut dst[(o0 + b) * rows..(o0 + b + 1) * rows];
            for (i, slot) in line.iter_mut().enumerate() {
                *slot = self.tile_in[i * bw + b];
            }
        }
    }
}

impl<T: Scalar> Default for LinePanel<T> {
    fn default() -> Self {
        LinePanel::new()
    }
}

/// Plain Thomas solve building its factorization on the fly (the non-IVER
/// path, recomputing auxiliary arrays for every line as the original method
/// does).
pub fn thomas_solve_fresh<T: Scalar>(f: &mut [T], h: f64) {
    let aux = ThomasAux::<T>::new(f.len(), h);
    aux.solve(f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn rand_line(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
    }

    #[test]
    fn direct_load_matches_assembly() {
        for &m in &[5usize, 9, 17, 33] {
            let c = rand_line(m, m as u64);
            let oracle = load_assembled(&c, 1.0);
            let mut fast = vec![0.0; m / 2 + 1];
            load_direct(&c, &mut fast, 1.0);
            for (a, b) in fast.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-12, "m={m}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn mass_restrict_matches_direct() {
        for &m in &[5usize, 9, 33, 65] {
            let c = rand_line(m, 7 + m as u64);
            let mut a = vec![0.0; m / 2 + 1];
            let mut b = vec![0.0; m / 2 + 1];
            let mut scratch = Vec::new();
            load_direct(&c, &mut a, 2.5);
            load_mass_restrict(&c, &mut b, 2.5, &mut scratch);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-12, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn h_scaling_is_linear() {
        let c = rand_line(9, 3);
        let mut f1 = vec![0.0; 5];
        let mut f2 = vec![0.0; 5];
        load_direct(&c, &mut f1, 1.0);
        load_direct(&c, &mut f2, 4.0);
        for (a, b) in f1.iter().zip(&f2) {
            assert!((a * 4.0 - b).abs() < 1e-12);
        }
    }

    /// Multiply the coarse mass matrix by x (dense reference).
    fn mass_mul(x: &[f64], h: f64) -> Vec<f64> {
        let n = x.len();
        let e = h / 3.0;
        let d_in = 4.0 * h / 3.0;
        let d_bd = 2.0 * h / 3.0;
        (0..n)
            .map(|i| {
                let d = if i == 0 || i == n - 1 { d_bd } else { d_in };
                let mut v = d * x[i];
                if i > 0 {
                    v += e * x[i - 1];
                }
                if i + 1 < n {
                    v += e * x[i + 1];
                }
                v
            })
            .collect()
    }

    #[test]
    fn thomas_inverts_mass() {
        for &n in &[2usize, 3, 5, 9, 17] {
            for &h in &[1.0, 2.0] {
                let x = rand_line(n, n as u64 * 31 + h as u64);
                let mut f = mass_mul(&x, h);
                let aux = ThomasAux::<f64>::new(n, h);
                aux.solve(&mut f);
                for (a, b) in f.iter().zip(&x) {
                    assert!((a - b).abs() < 1e-10, "n={n} h={h}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn fresh_equals_precomputed() {
        let x = rand_line(9, 5);
        let mut a = x.clone();
        let mut b = x.clone();
        thomas_solve_fresh(&mut a, 3.0);
        ThomasAux::<f64>::new(9, 3.0).solve(&mut b);
        for (p, q) in a.iter().zip(&b) {
            assert!((p - q).abs() < 1e-14);
        }
    }

    #[test]
    fn batch_solve_matches_scalar() {
        let n = 9;
        let batch = 7;
        let aux = ThomasAux::<f64>::new(n, 1.0);
        // build interleaved batch from independent lines
        let lines: Vec<Vec<f64>> = (0..batch).map(|b| rand_line(n, 100 + b as u64)).collect();
        let mut inter = vec![0.0; n * batch];
        for (b, line) in lines.iter().enumerate() {
            for i in 0..n {
                inter[i * batch + b] = line[i];
            }
        }
        aux.solve_batch(&mut inter, batch);
        for (b, line) in lines.iter().enumerate() {
            let mut expect = line.clone();
            aux.solve(&mut expect);
            for i in 0..n {
                assert!(
                    (inter[i * batch + b] - expect[i]).abs() < 1e-12,
                    "line {b} row {i}"
                );
            }
        }
    }

    /// Interleave `bw` lines of length `n` into the lane layout.
    fn interleave(lines: &[Vec<f64>], n: usize) -> Vec<f64> {
        let bw = lines.len();
        let mut tile = vec![0.0; n * bw];
        for (b, line) in lines.iter().enumerate() {
            for i in 0..n {
                tile[i * bw + b] = line[i];
            }
        }
        tile
    }

    #[test]
    fn panel_load_kernels_bit_identical_to_per_line() {
        for &m in &[5usize, 9, 17, 33] {
            for &bw in &[1usize, 2, 3, 7, 16] {
                let lines: Vec<Vec<f64>> =
                    (0..bw).map(|b| rand_line(m, 2000 + (m * 37 + b) as u64)).collect();
                let tile = interleave(&lines, m);
                let nc = m / 2 + 1;
                for &h in &[1.0, 2.5] {
                    // load_direct
                    let mut panel_out = vec![0.0; nc * bw];
                    load_direct_panel(&tile, &mut panel_out, bw, h);
                    for (b, line) in lines.iter().enumerate() {
                        let mut expect = vec![0.0; nc];
                        load_direct(line, &mut expect, h);
                        for i in 0..nc {
                            assert_eq!(
                                panel_out[i * bw + b].to_bits(),
                                expect[i].to_bits(),
                                "load_direct m={m} bw={bw} h={h} line {b} row {i}"
                            );
                        }
                    }
                    // load_mass_restrict
                    let mut w = Vec::new();
                    load_mass_restrict_panel(&tile, &mut panel_out, bw, h, &mut w);
                    let mut scratch = Vec::new();
                    for (b, line) in lines.iter().enumerate() {
                        let mut expect = vec![0.0; nc];
                        load_mass_restrict(line, &mut expect, h, &mut scratch);
                        for i in 0..nc {
                            assert_eq!(
                                panel_out[i * bw + b].to_bits(),
                                expect[i].to_bits(),
                                "mass_restrict m={m} bw={bw} h={h} line {b} row {i}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn blocked_batch_solve_bit_identical_to_scalar() {
        let n = 17;
        for &batch in &[1usize, 2, 5, 13, 64] {
            // every panel width including 1 and wider than the batch
            for &panel in &[0usize, 1, 2, 3, batch, batch + 9] {
                let aux = ThomasAux::<f64>::new(n, 1.0);
                let lines: Vec<Vec<f64>> =
                    (0..batch).map(|b| rand_line(n, 3000 + b as u64)).collect();
                let mut tile = interleave(&lines, n);
                aux.solve_batch_blocked(&mut tile, batch, panel);
                for (b, line) in lines.iter().enumerate() {
                    let mut expect = line.clone();
                    aux.solve(&mut expect);
                    for i in 0..n {
                        assert_eq!(
                            tile[i * batch + b].to_bits(),
                            expect[i].to_bits(),
                            "batch={batch} panel={panel} line {b} row {i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn line_panel_gather_scatter_round_trip() {
        let (n, outer) = (9usize, 11usize);
        let src: Vec<f64> = (0..n * outer).map(|i| i as f64 * 0.5 - 3.0).collect();
        let mut panel = LinePanel::<f64>::new();
        let mut dst = vec![0.0; n * outer];
        let mut o0 = 0;
        while o0 < outer {
            let bw = 4.min(outer - o0);
            panel.gather(&src, o0, n, bw);
            panel.scatter_in(&mut dst, o0, n, bw);
            o0 += bw;
        }
        assert_eq!(src, dst);
    }

    #[test]
    fn f32_precision_reasonable() {
        let n = 33;
        let x64 = rand_line(n, 9);
        let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
        let mut f64v = mass_mul(&x64, 1.0);
        let mut f32v: Vec<f32> = f64v.iter().map(|&v| v as f32).collect();
        ThomasAux::<f64>::new(n, 1.0).solve(&mut f64v);
        ThomasAux::<f32>::new(n, 1.0).solve(&mut f32v);
        for (a, b) in f32v.iter().zip(&f64v) {
            assert!((*a as f64 - b).abs() < 1e-4);
        }
    }
}
