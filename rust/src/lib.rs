//! # mgardp — MGARD+ multilevel error-bounded scientific data reduction
//!
//! A production reproduction of *MGARD+: Optimizing Multilevel Methods for
//! Error-bounded Scientific Data Reduction* (Liang et al., 2020).
//!
//! The crate is the Layer-3 hot path of a three-layer Rust + JAX + Pallas
//! stack: everything needed to compress, decompress, refactor and analyze
//! scientific floating-point data runs natively in Rust; the JAX/Pallas
//! layers (under `python/`) AOT-compile an XLA backend for the multilevel
//! decomposition which `runtime` can load and execute via PJRT.
//!
//! Quick start:
//! ```
//! use mgardp::compressors::{Compressor, MgardPlus, Tolerance};
//! let field = mgardp::data::synth::smooth_test_field(&[17, 17, 17]);
//! let codec = MgardPlus::default();
//! let compressed = codec.compress(&field, Tolerance::Rel(1e-3)).unwrap();
//! let restored = codec.decompress(&compressed).unwrap();
//! let tau = 1e-3 * mgardp::metrics::value_range(field.data());
//! assert!(mgardp::metrics::linf_error(field.data(), restored.data()) <= tau);
//! ```

pub mod adaptive;
pub mod analysis;
pub mod bench_util;
pub mod chunk;
pub mod compressors;
pub mod coordinator;
pub mod data;
pub mod decompose;
pub mod encode;
pub mod error;
pub mod grid;
pub mod metrics;
pub mod obs;
pub mod progressive;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod storage;
pub mod stream;
pub mod tensor;

pub use error::{Error, Result};

/// Convenience re-exports for downstream users.
pub mod prelude {
    pub use crate::error::{Error, Result};
    pub use crate::grid::Hierarchy;
    pub use crate::metrics::{psnr, RateDistortionPoint};
    pub use crate::tensor::Tensor;
}
