//! Penalty factors (§4.2.2): the expected extra prediction error incurred by
//! predicting from *reconstructed* rather than original data.
//!
//! All factors are expressed per unit tolerance (multiply by `τ_0`). They are
//! computed once per dimensionality by deterministic Monte-Carlo, exactly as
//! the paper derives them ("using the statistical method"), and cached:
//!
//! * Lorenzo: the prediction is a ±1 combination of `2^d − 1` reconstructed
//!   neighbors, each with error `U(−τ,τ)`; the paper reports `E|·| = 1.22τ`
//!   for 3-D, which our Monte-Carlo reproduces.
//! * Multilinear interpolation: a nodal node's error is its own quantization
//!   error `U(−τ,τ)` *plus* the correction error induced by quantized
//!   coefficients, which is approximately Gaussian; the paper reports
//!   `σ = 0.283τ` for 3-D. We *measure* σ by pushing uniform coefficient
//!   errors through this implementation's actual correction operator, then
//!   Monte-Carlo the per-category penalties (edge/plane/cube generalize to
//!   categories `q = 1..=d`, the number of interpolated dimensions).

use crate::data::rng::Rng;
use crate::decompose::{contiguous, OptFlags};

use std::sync::OnceLock;

const MC_SAMPLES: usize = 400_000;

/// `E|Σ_{i=1}^{2^d-1} U(-1,1)|` — the Lorenzo penalty factor for `d` dims
/// (1.22 for 3-D, Table/§4.2.2 of the paper).
pub fn lorenzo_penalty_factor(d: usize) -> f64 {
    static CACHE: OnceLock<[f64; 5]> = OnceLock::new();
    let cache = CACHE.get_or_init(|| {
        let mut out = [0.0; 5];
        for (dd, slot) in out.iter_mut().enumerate().skip(1) {
            let k = (1usize << dd) - 1;
            let mut rng = Rng::new(0x4C6F_7265 + dd as u64);
            let mut acc = 0.0;
            for _ in 0..MC_SAMPLES {
                let mut s = 0.0;
                for _ in 0..k {
                    s += rng.uniform_in(-1.0, 1.0);
                }
                acc += s.abs();
            }
            *slot = acc / MC_SAMPLES as f64;
        }
        out
    });
    assert!((1..=4).contains(&d), "penalties support 1..=4 dims");
    cache[d]
}

/// Standard deviation (per unit τ) of the correction values produced when
/// the level's coefficient nodes carry `U(−τ,τ)` errors — measured through
/// the actual correction operator of this crate (paper: `0.283τ` for 3-D).
pub fn correction_error_sd(d: usize) -> f64 {
    static CACHE: OnceLock<[f64; 5]> = OnceLock::new();
    let cache = CACHE.get_or_init(|| {
        let mut out = [0.0; 5];
        for (dd, slot) in out.iter_mut().enumerate().skip(1) {
            *slot = measure_correction_sd(dd);
        }
        out
    });
    assert!((1..=4).contains(&d));
    cache[d]
}

fn measure_correction_sd(d: usize) -> f64 {
    // grid large enough for the statistic to stabilize, small enough to be
    // instant; the paper notes independence from the grid extent
    let n = if d >= 4 { 9 } else { 17 };
    let shape = vec![n; d];
    let mut rng = Rng::new(0x5344_5344 + d as u64);
    let mut acc2 = 0.0f64;
    let mut count = 0usize;
    for _ in 0..8 {
        // coefficient-node errors uniform in (-1, 1); nodal zero
        let mut e = vec![0.0f64; shape.iter().product()];
        fill_coeff_noise(&mut e, &shape, &mut rng);
        let corr = contiguous::correction_of_component(&e, &shape, OptFlags::all());
        for v in corr {
            acc2 += v * v;
            count += 1;
        }
    }

    (acc2 / count as f64).sqrt()
}

fn fill_coeff_noise(e: &mut [f64], shape: &[usize], rng: &mut Rng) {
    let d = shape.len();
    let mut idx = vec![0usize; d];
    for item in e.iter_mut() {
        let nodal = idx.iter().all(|&i| i % 2 == 0);
        *item = if nodal { 0.0 } else { rng.uniform_in(-1.0, 1.0) };
        for k in (0..d).rev() {
            idx[k] += 1;
            if idx[k] < shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
}

/// Per-category interpolation penalty factors, indexed by `q` = number of
/// interpolated dims (index 0 unused). For 3-D: `[_, edge, plane, cube]` ≈
/// `[_, 0.369, 0.259, 0.182]` (paper §4.2.2).
pub fn interp_penalties(d: usize) -> [f64; 5] {
    static CACHE: OnceLock<[[f64; 5]; 5]> = OnceLock::new();
    let cache = CACHE.get_or_init(|| {
        let mut out = [[0.0; 5]; 5];
        for dd in 1..=4 {
            let sd = correction_error_sd(dd);
            let mut rng = Rng::new(0x494E_5450 + dd as u64);
            for q in 1..=dd {
                let corners = 1usize << q;
                let mut acc = 0.0;
                for _ in 0..MC_SAMPLES {
                    let mut s = 0.0;
                    for _ in 0..corners {
                        // nodal error = quantization U(-1,1) + correction N(0, sd)
                        s += rng.uniform_in(-1.0, 1.0) + sd * rng.normal();
                    }
                    acc += (s / corners as f64).abs();
                }
                out[dd][q] = acc / MC_SAMPLES as f64;
            }
        }
        out
    });
    assert!((1..=4).contains(&d));
    cache[d]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lorenzo_3d_matches_paper() {
        let f = lorenzo_penalty_factor(3);
        assert!((f - 1.22).abs() < 0.02, "3-D Lorenzo penalty {f} vs paper 1.22");
    }

    #[test]
    fn lorenzo_1d_exact_half() {
        let f = lorenzo_penalty_factor(1);
        assert!((f - 0.5).abs() < 0.01, "1-D E|U(-1,1)| = 0.5, got {f}");
    }

    #[test]
    fn lorenzo_grows_with_dimension() {
        assert!(lorenzo_penalty_factor(1) < lorenzo_penalty_factor(2));
        assert!(lorenzo_penalty_factor(2) < lorenzo_penalty_factor(3));
        assert!(lorenzo_penalty_factor(3) < lorenzo_penalty_factor(4));
    }

    #[test]
    fn correction_sd_3d_near_paper() {
        let sd = correction_error_sd(3);
        // paper reports 0.283 for their operator; ours should be the same
        // order (the grids and stencils match)
        assert!(
            (0.15..0.45).contains(&sd),
            "3-D correction sd {sd} far from paper's 0.283"
        );
    }

    #[test]
    fn interp_penalties_3d_ordered_like_paper() {
        let p = interp_penalties(3);
        // edge > plane > cube (more corners average the noise down)
        assert!(p[1] > p[2] && p[2] > p[3], "{p:?}");
        // magnitudes near paper's 0.369 / 0.259 / 0.182
        assert!((p[1] - 0.369).abs() < 0.06, "edge {}", p[1]);
        assert!((p[2] - 0.259).abs() < 0.05, "plane {}", p[2]);
        assert!((p[3] - 0.182).abs() < 0.04, "cube {}", p[3]);
    }

    #[test]
    fn interp_penalty_below_lorenzo() {
        // the paper's key observation: interpolation is less sensitive to
        // reconstructed-data errors than Lorenzo
        for d in 1..=4 {
            let p = interp_penalties(d);
            for q in 1..=d {
                assert!(p[q] < lorenzo_penalty_factor(d));
            }
        }
    }
}
