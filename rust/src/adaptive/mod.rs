//! Adaptive decomposition termination (§4.2).
//!
//! At each level the compressor asks: will the *next* prediction step be
//! served better by the multilevel method's piecewise multilinear
//! interpolation, or by the external compressor's Lorenzo predictor? Both
//! are estimated from *original* data plus a penalty factor modelling the
//! effect of working with reconstructed data (§4.2.2), on a 1-in-4ᵈ sample
//! of 3ᵈ blocks (§4.2.3). When Lorenzo wins, decomposition terminates and
//! the remaining coarse representation goes to the external compressor.

mod penalty;

pub use penalty::{interp_penalties, lorenzo_penalty_factor, correction_error_sd};

use crate::tensor::Scalar;

/// Estimated aggregate prediction errors for the two candidate predictors
/// at one level (§4.2.3, Alg. 1 lines 5–9).
#[derive(Clone, Copy, Debug)]
pub struct PredictorEstimate {
    /// Aggregate estimated Lorenzo error (Eq. 3).
    pub lorenzo: f64,
    /// Aggregate estimated multilinear-interpolation error (Eq. 4).
    pub interp: f64,
    /// Number of coefficient nodes sampled.
    pub samples: usize,
}

impl PredictorEstimate {
    /// Terminate the decomposition when Lorenzo is strictly better.
    pub fn should_terminate(&self) -> bool {
        self.samples > 0 && self.lorenzo < self.interp
    }
}

/// d-dimensional Lorenzo prediction at `flat` from already-visited neighbors
/// (all 2^d−1 sign-alternating corners of the trailing unit cube).
#[inline]
fn lorenzo_pred<T: Scalar>(data: &[T], flat: usize, strides: &[usize]) -> f64 {
    let d = strides.len();
    let mut acc = 0.0f64;
    for mask in 1..(1usize << d) {
        let mut off = flat;
        for (k, &s) in strides.iter().enumerate() {
            if mask & (1 << k) != 0 {
                off -= s;
            }
        }
        let sign = if mask.count_ones() % 2 == 1 { 1.0 } else { -1.0 };
        acc += sign * data[off].to_f64();
    }
    acc
}

/// Multilinear interpolation prediction at a coefficient node with odd-dim
/// strides `odd` (the nodal corners of its cell).
#[inline]
fn interp_pred<T: Scalar>(data: &[T], flat: usize, odd: &[usize]) -> f64 {
    let q = odd.len();
    let mut acc = 0.0f64;
    for mask in 0..(1usize << q) {
        let mut off = flat;
        for (b, &s) in odd.iter().enumerate() {
            if mask & (1 << b) != 0 {
                off += s;
            } else {
                off -= s;
            }
        }
        acc += data[off].to_f64();
    }
    acc / (1usize << q) as f64
}

/// Estimate both predictors' errors on a contiguous level array of `shape`
/// under level tolerance `tau0`, sampling one out of `sample_stride` blocks
/// along each dimension (the paper samples 1-in-4).
pub fn estimate_predictors<T: Scalar>(
    data: &[T],
    shape: &[usize],
    tau0: f64,
    sample_stride: usize,
) -> PredictorEstimate {
    let d = shape.len();
    let strides = crate::tensor::strides_for(shape);
    let active: Vec<bool> = shape.iter().map(|&n| n >= 5).collect();
    let lorenzo_factor = lorenzo_penalty_factor(d) * tau0;
    let interp_factors = interp_penalties(d);
    let mut est = PredictorEstimate {
        lorenzo: 0.0,
        interp: 0.0,
        samples: 0,
    };
    // iterate sampled 3^d block origins: block b starts at node 2b per dim
    let nblocks: Vec<usize> = shape
        .iter()
        .map(|&n| if n >= 3 { (n - 1) / 2 } else { 1 })
        .collect();
    let mut block = vec![0usize; d];
    loop {
        // per-block: iterate the 3^d nodes; coefficient nodes have odd offset
        let mut offs = vec![0usize; d];
        'nodes: loop {
            let mut flat = 0usize;
            let mut odd: Vec<usize> = Vec::with_capacity(d);
            let mut boundary_ok = true;
            for k in 0..d {
                let ix = 2 * block[k] + offs[k];
                if ix >= shape[k] {
                    boundary_ok = false;
                    break;
                }
                flat += ix * strides[k];
                if active[k] && offs[k] % 2 == 1 {
                    odd.push(strides[k]);
                }
                if ix == 0 {
                    // Lorenzo needs all trailing neighbors; skip domain edge
                    boundary_ok = boundary_ok && false;
                }
            }
            if boundary_ok && !odd.is_empty() {
                let v = data[flat].to_f64();
                let lp = lorenzo_pred(data, flat, &strides);
                let ip = interp_pred(data, flat, &odd);
                est.lorenzo += (lp - v).abs() + lorenzo_factor;
                est.interp += (ip - v).abs() + interp_factors[odd.len()] * tau0;
                est.samples += 1;
            }
            // advance node offset
            for k in (0..d).rev() {
                offs[k] += 1;
                if offs[k] < 2 {
                    continue 'nodes;
                }
                offs[k] = 0;
            }
            break;
        }
        // advance sampled block origin
        let mut carry = true;
        for k in (0..d).rev() {
            if !carry {
                break;
            }
            block[k] += sample_stride;
            if block[k] < nblocks[k] {
                carry = false;
            } else {
                block[k] = 0;
            }
        }
        if carry {
            break;
        }
    }
    est
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::tensor::Tensor;

    #[test]
    fn lorenzo_pred_matches_paper_formula_3d() {
        // pred = u110+u101+u011-u100-u010-u001+u000 for the corner offsets
        let shape = [2usize, 2, 2];
        let vals: Vec<f64> = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        // index (i,j,k) -> val = data[4i+2j+k]
        let t = Tensor::from_vec(&shape, vals).unwrap();
        let strides = [4usize, 2, 1];
        let pred = lorenzo_pred(t.data(), 7, &strides);
        // u110=7, u101=6, u011=4, u100=5, u010=3, u001=2, u000=1
        assert!((pred - (7.0 + 6.0 + 4.0 - 5.0 - 3.0 - 2.0 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn smooth_data_favours_interp_at_high_tolerance() {
        // Very smooth field + large tau: Lorenzo's penalty dominates, so the
        // multilevel interpolation should win (decomposition continues).
        let shape = [33usize, 33, 33];
        let t = Tensor::<f64>::from_fn(&shape, |ix| {
            let x = ix[0] as f64 / 32.0;
            let y = ix[1] as f64 / 32.0;
            let z = ix[2] as f64 / 32.0;
            (2.0 * x + y).sin() + (z - 0.3 * y).cos()
        });
        let est = estimate_predictors(t.data(), &shape, 0.05, 4);
        assert!(est.samples > 0);
        assert!(
            !est.should_terminate(),
            "interp should win on smooth data at high tol: {est:?}"
        );
    }

    #[test]
    fn rough_data_low_tolerance_favours_lorenzo() {
        // White noise at tiny tolerance: the high-order Lorenzo predictor has
        // no penalty to pay and both predict poorly, but interpolation's
        // structural error is comparable; with tau -> 0 penalties vanish and
        // the decision is driven by raw prediction error. Use a field with
        // strong high-order structure where Lorenzo excels: a quadratic.
        let shape = [17usize, 17, 17];
        let t = Tensor::<f64>::from_fn(&shape, |ix| {
            let x = ix[0] as f64;
            let y = ix[1] as f64;
            let z = ix[2] as f64;
            x * x + y * y + z * z + x * y + 0.5 * y * z
        });
        let est = estimate_predictors(t.data(), &shape, 1e-9, 4);
        assert!(est.samples > 0);
        assert!(
            est.should_terminate(),
            "Lorenzo (2nd order) should beat linear interp on quadratics: {est:?}"
        );
    }

    #[test]
    fn sampling_stride_reduces_samples() {
        let mut rng = Rng::new(4);
        let shape = [33usize, 33];
        let t = Tensor::<f64>::from_fn(&shape, |_| rng.uniform());
        let dense = estimate_predictors(t.data(), &shape, 0.01, 1);
        let sparse = estimate_predictors(t.data(), &shape, 0.01, 4);
        assert!(sparse.samples < dense.samples);
    }
}
