//! Quality and performance metrics (§3 of the paper).
//!
//! Quality is rate–distortion: bit-rate (bits per datum of the compressed
//! representation) vs PSNR. Performance is throughput (original bytes per
//! second of wall-clock for the operation).

use crate::tensor::Scalar;

/// Peak signal-to-noise ratio in dB, exactly the paper's formula:
/// `PSNR = 20·log10(range) − 10·log10(MSE)` with `range = max(u) − min(u)`.
pub fn psnr<T: Scalar>(original: &[T], reconstructed: &[T]) -> f64 {
    assert_eq!(original.len(), reconstructed.len());
    let range = value_range(original);
    let mse = mse(original, reconstructed);
    if mse == 0.0 {
        return f64::INFINITY;
    }
    20.0 * range.log10() - 10.0 * mse.log10()
}

/// Mean squared error.
pub fn mse<T: Scalar>(a: &[T], b: &[T]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = x.to_f64() - y.to_f64();
        acc += d * d;
    }
    acc / a.len() as f64
}

/// L2 norm of the error vector (not averaged).
pub fn l2_error<T: Scalar>(a: &[T], b: &[T]) -> f64 {
    (mse(a, b) * a.len() as f64).sqrt()
}

/// Maximum absolute pointwise error (the bound every compressor must honour).
pub fn linf_error<T: Scalar>(a: &[T], b: &[T]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut mx = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (x.to_f64() - y.to_f64()).abs();
        if d > mx {
            mx = d;
        }
    }
    mx
}

/// `max − min` of a slice as f64.
pub fn value_range<T: Scalar>(data: &[T]) -> f64 {
    let mut mn = f64::INFINITY;
    let mut mx = f64::NEG_INFINITY;
    for v in data {
        let v = v.to_f64();
        if v < mn {
            mn = v;
        }
        if v > mx {
            mx = v;
        }
    }
    mx - mn
}

/// Compression ratio: original bytes / compressed bytes.
pub fn compression_ratio(original_bytes: usize, compressed_bytes: usize) -> f64 {
    original_bytes as f64 / compressed_bytes as f64
}

/// Bit-rate: average compressed bits per data point.
pub fn bit_rate(compressed_bytes: usize, num_points: usize) -> f64 {
    compressed_bytes as f64 * 8.0 / num_points as f64
}

/// Throughput in MB/s given payload bytes and elapsed seconds.
pub fn throughput_mbs(bytes: usize, seconds: f64) -> f64 {
    bytes as f64 / 1e6 / seconds
}

/// One point on a rate–distortion curve (Figs. 10–12).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RateDistortionPoint {
    /// Requested relative error tolerance that produced this point.
    pub tolerance: f64,
    /// Bits per data point.
    pub bit_rate: f64,
    /// PSNR in dB.
    pub psnr: f64,
    /// Compression ratio.
    pub ratio: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn psnr_of_identical_is_infinite() {
        let a = vec![1.0f32, 2.0, 3.0];
        assert!(psnr(&a, &a).is_infinite());
    }

    #[test]
    fn psnr_known_value() {
        // range 1, constant error 0.1 -> PSNR = -10*log10(0.01) = 20 dB
        let a: Vec<f64> = (0..100).map(|i| i as f64 / 99.0).collect();
        let b: Vec<f64> = a.iter().map(|v| v + 0.1).collect();
        let p = psnr(&a, &b);
        assert!((p - 20.0).abs() < 1e-9, "psnr {p}");
    }

    #[test]
    fn linf_picks_max() {
        let a = vec![0.0f32, 0.0, 0.0];
        let b = vec![0.1f32, -0.5, 0.2];
        assert!((linf_error(&a, &b) - 0.5).abs() < 1e-7);
    }

    #[test]
    fn ratios_and_rates() {
        assert_eq!(compression_ratio(1000, 10), 100.0);
        // 4-byte floats compressed 8x -> 4 bits/value
        assert_eq!(bit_rate(500, 1000), 4.0);
        assert_eq!(throughput_mbs(2_000_000, 2.0), 1.0);
    }

    #[test]
    fn higher_error_lower_psnr() {
        let a: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.01).sin()).collect();
        let small: Vec<f32> = a.iter().map(|v| v + 0.001).collect();
        let big: Vec<f32> = a.iter().map(|v| v + 0.1).collect();
        assert!(psnr(&a, &small) > psnr(&a, &big));
    }
}
