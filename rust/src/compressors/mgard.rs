//! The original multilevel compressor (MGARD, [11]): full decomposition plus
//! *uniform* quantization across levels — the baseline that §4's techniques
//! improve on (cyan curve in Fig. 10).

use super::format::{Header, Method};
use super::{Compressor, Tolerance};
use crate::decompose::{Decomposer, Decomposition, OptFlags};
use crate::encode::varint::{write_section, write_u64, ByteReader};
use crate::encode::{huffman_decode, huffman_encode, lossless_compress, lossless_decompress};
use crate::error::{Error, Result};
use crate::grid::Hierarchy;
use crate::quant::{dequantize, quantize, QuantStream, DEFAULT_C_LINF};
use crate::tensor::{Scalar, Tensor};

/// MGARD configuration.
#[derive(Clone, Copy, Debug)]
pub struct MgardConfig {
    /// Engine used for decomposition timing studies. The *compressed format*
    /// is engine-independent; Fig. 8 benchmarks the original (baseline)
    /// engine, which is the default here because this type *is* the original
    /// MGARD.
    pub flags: OptFlags,
    /// L∞ constant for distributing the error budget.
    pub c_linf: f64,
    /// Cap on decomposition depth (None = as deep as possible).
    pub max_levels: Option<usize>,
    /// Lossless-stage effort level (kept as `zstd_level` for config compatibility).
    pub zstd_level: i32,
}

impl Default for MgardConfig {
    fn default() -> Self {
        MgardConfig {
            flags: OptFlags::baseline(),
            c_linf: DEFAULT_C_LINF,
            max_levels: None,
            zstd_level: 3,
        }
    }
}

/// The original multilevel compressor.
#[derive(Clone, Debug, Default)]
pub struct Mgard {
    cfg: MgardConfig,
}

impl Mgard {
    /// Build with an explicit configuration.
    pub fn new(cfg: MgardConfig) -> Self {
        Mgard { cfg }
    }

    /// MGARD but running on the optimized engine (used by throughput benches
    /// to separate algorithmic from format effects).
    pub fn optimized_engine() -> Self {
        Mgard::new(MgardConfig {
            flags: OptFlags::all(),
            ..MgardConfig::default()
        })
    }
}

impl<T: Scalar> Compressor<T> for Mgard {
    fn name(&self) -> &'static str {
        "MGARD"
    }

    fn compress(&self, data: &Tensor<T>, tol: Tolerance) -> Result<Vec<u8>> {
        let tau = tol.absolute(data.value_range());
        if tau <= 0.0 {
            return Err(Error::invalid("tolerance must be positive"));
        }
        let hierarchy = Hierarchy::new(data.shape(), self.cfg.max_levels)?;
        let dec = Decomposer::new(hierarchy.clone(), self.cfg.flags)?.decompose(data)?;
        let levels = hierarchy.nlevels() + 1;
        // uniform split of the L∞ budget across all levels (the pre-§4.1
        // strategy): every tier gets τ / (C · #tiers)
        let tau_level = tau / (self.cfg.c_linf * levels as f64);

        let mut qs = QuantStream::default();
        quantize(dec.coarse.data(), tau_level, &mut qs);
        for stream in &dec.coeffs {
            quantize(stream, tau_level, &mut qs);
        }

        let mut payload = Vec::new();
        write_u64(&mut payload, self.cfg.max_levels.map_or(0, |v| v as u64 + 1));
        write_section(&mut payload, &huffman_encode(&qs.symbols));
        write_section(&mut payload, &qs.escapes_to_bytes());
        let compressed = lossless_compress(&payload, self.cfg.zstd_level)?;

        let mut out = Vec::with_capacity(compressed.len() + 64);
        Header {
            method: Method::Mgard,
            dtype: T::DTYPE_TAG,
            shape: data.shape().to_vec(),
            tau_abs: tau,
        }
        .write(&mut out);
        write_u64(&mut out, payload.len() as u64);
        out.extend_from_slice(&compressed);
        Ok(out)
    }

    fn decompress(&self, bytes: &[u8]) -> Result<Tensor<T>> {
        let (header, mut r) = Header::read(bytes)?;
        header.expect::<T>(Method::Mgard)?;
        let payload_len = r.usize()?;
        let payload = lossless_decompress(r.bytes(r.remaining())?, payload_len)?;
        let mut pr = ByteReader::new(&payload);
        let max_levels_enc = pr.usize()?;
        let max_levels = if max_levels_enc == 0 {
            None
        } else {
            Some(max_levels_enc - 1)
        };
        let symbols = huffman_decode(pr.section()?)?;
        let escapes = QuantStream::escapes_from_bytes(pr.section()?)?;

        let hierarchy = Hierarchy::new(&header.shape, max_levels)?;
        let levels = hierarchy.nlevels() + 1;
        let tau_level = header.tau_abs / (self.cfg.c_linf * levels as f64);

        // expected stream lengths
        let coarse_n = hierarchy.level_numel(0);
        let mut cursor = 0usize;
        let mut esc_cursor = 0usize;
        let take = |cursor: &mut usize, n: usize| -> Result<std::ops::Range<usize>> {
            if *cursor + n > symbols.len() {
                return Err(Error::corrupt("quantized stream too short"));
            }
            let r = *cursor..*cursor + n;
            *cursor += n;
            Ok(r)
        };
        let mut coarse_vals: Vec<T> = Vec::with_capacity(coarse_n);
        dequantize(
            &symbols[take(&mut cursor, coarse_n)?],
            &escapes,
            &mut esc_cursor,
            tau_level,
            &mut coarse_vals,
        )?;
        let mut coeffs = Vec::with_capacity(hierarchy.nlevels());
        for l in 1..=hierarchy.nlevels() {
            let n = hierarchy.num_coeff_nodes(l);
            let mut vals: Vec<T> = Vec::with_capacity(n);
            dequantize(
                &symbols[take(&mut cursor, n)?],
                &escapes,
                &mut esc_cursor,
                tau_level,
                &mut vals,
            )?;
            coeffs.push(vals);
        }

        let dec = Decomposition {
            hierarchy: hierarchy.clone(),
            start_level: 0,
            coarse: Tensor::from_vec(&hierarchy.level_shape(0), coarse_vals)?,
            coeffs,
        };
        // decompression always uses the fast engine (identical math)
        Decomposer::new(hierarchy, OptFlags::all())?.recompose(&dec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::linf_error;

    #[test]
    fn error_bound_smooth_field() {
        let t = crate::data::synth::smooth_test_field(&[17, 17, 17]);
        let m = Mgard::optimized_engine();
        for tau in [1e-1, 1e-2, 1e-3] {
            let bytes = m.compress(&t, Tolerance::Abs(tau)).unwrap();
            let back: Tensor<f32> = m.decompress(&bytes).unwrap();
            let err = linf_error(t.data(), back.data());
            assert!(err <= tau, "τ={tau}: err {err}");
        }
    }

    #[test]
    fn baseline_and_optimized_engines_interoperate() {
        let t = crate::data::synth::smooth_test_field(&[9, 12]);
        let slow = Mgard::default(); // baseline engine
        let bytes = slow.compress(&t, Tolerance::Abs(1e-2)).unwrap();
        // decompress (always fast engine) must still honour the bound
        let back: Tensor<f32> = slow.decompress(&bytes).unwrap();
        assert!(linf_error(t.data(), back.data()) <= 1e-2);
    }

    #[test]
    fn compresses_smooth_data_well() {
        let t = crate::data::synth::smooth_test_field(&[33, 33, 33]);
        let m = Mgard::optimized_engine();
        let bytes = m.compress(&t, Tolerance::Rel(1e-2)).unwrap();
        assert!(
            bytes.len() < t.nbytes() / 8,
            "CR too low: {} vs {}",
            bytes.len(),
            t.nbytes()
        );
    }

    #[test]
    fn max_levels_round_trips_through_container() {
        let t = crate::data::synth::smooth_test_field(&[17, 17]);
        let m = Mgard::new(MgardConfig {
            flags: OptFlags::all(),
            max_levels: Some(2),
            ..MgardConfig::default()
        });
        let bytes = m.compress(&t, Tolerance::Abs(1e-2)).unwrap();
        let back: Tensor<f32> = m.decompress(&bytes).unwrap();
        assert!(linf_error(t.data(), back.data()) <= 1e-2);
    }
}
