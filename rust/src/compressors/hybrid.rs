//! The hybrid prediction model [9]: ZFP's non-orthogonal transform embedded
//! as a third per-block coding mode inside the SZ framework.
//!
//! Each 4ᵈ block selects among Lorenzo, block-local linear regression and
//! transform coding by *actually trial-encoding* the transform candidate and
//! estimating the entropy of the prediction candidates — the costly
//! selection that makes the hybrid model's compression roughly half SZ's
//! speed in Fig. 8 while improving the ratio on transform-friendly data.

use super::format::{Header, Method};
use super::zfp::{decode_block_f64, encode_block_f64, intprec};
use super::{CodecScratch, Compressor, HybridScratch, Tolerance};
use crate::encode::varint::{write_i64, write_section, write_u64, ByteReader};
use crate::encode::{huffman_decode, huffman_encode, lossless_compress, lossless_decompress};
use crate::encode::{BitReader, BitWriter};
use crate::error::{Error, Result};
use crate::tensor::{strides_for, Scalar, Tensor};

const EDGE: usize = 4;

/// Hybrid-model configuration.
#[derive(Clone, Copy, Debug)]
pub struct HybridConfig {
    /// Quantization radius for the prediction modes.
    pub radius: i64,
    /// Lossless-stage effort level (kept as `zstd_level` for config compatibility).
    pub zstd_level: i32,
}

impl Default for HybridConfig {
    fn default() -> Self {
        HybridConfig {
            radius: 32768,
            zstd_level: 3,
        }
    }
}

/// The hybrid compressor.
#[derive(Clone, Debug, Default)]
pub struct Hybrid {
    cfg: HybridConfig,
}

impl Hybrid {
    /// Build with an explicit configuration.
    pub fn new(cfg: HybridConfig) -> Self {
        Hybrid { cfg }
    }

    /// Wrap into a block-parallel compressor (see [`crate::chunk`]),
    /// mirroring [`super::MgardPlus::chunked`]. Out-of-core fields stream
    /// through the same pipeline via [`crate::stream`].
    pub fn chunked(
        self,
        cfg: crate::chunk::ChunkedConfig,
    ) -> crate::chunk::ChunkedCompressor<Self> {
        crate::chunk::ChunkedCompressor::new(self, cfg)
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Mode {
    Lorenzo = 0,
    Regression = 1,
    Transform = 2,
}

impl Mode {
    fn from_u8(v: u8) -> Result<Mode> {
        Ok(match v {
            0 => Mode::Lorenzo,
            1 => Mode::Regression,
            2 => Mode::Transform,
            other => return Err(Error::corrupt(format!("hybrid mode {other}"))),
        })
    }
}

#[inline]
fn lorenzo_pred<T: Scalar>(recon: &[T], idx: &[usize], strides: &[usize]) -> f64 {
    let d = idx.len();
    let mut acc = 0.0f64;
    'mask: for mask in 1..(1usize << d) {
        let mut off = 0usize;
        for k in 0..d {
            if mask & (1 << k) != 0 {
                if idx[k] == 0 {
                    continue 'mask;
                }
                off += (idx[k] - 1) * strides[k];
            } else {
                off += idx[k] * strides[k];
            }
        }
        let sign = if mask.count_ones() % 2 == 1 { 1.0 } else { -1.0 };
        acc += sign * recon[off].to_f64();
    }
    acc
}

/// Least-squares linear fit over the block; writes the `d + 1` coefficients
/// (intercept first) into `out`. Allocation-free: the per-dim accumulators
/// live on fixed-size stacks (`d <= 4`).
fn fit_regression<T: Scalar>(
    data: &[T],
    strides: &[usize],
    origin: &[usize],
    bsize: &[usize],
    out: &mut [f64],
) {
    let d = bsize.len();
    debug_assert_eq!(out.len(), d + 1);
    let n: usize = bsize.iter().product();
    let mut centers = [0.0f64; 4];
    let mut vars = [0.0f64; 4];
    for (k, &b) in bsize.iter().enumerate() {
        let c = (b as f64 - 1.0) / 2.0;
        centers[k] = c;
        vars[k] = (0..b).map(|i| (i as f64 - c).powi(2)).sum::<f64>() / b as f64;
    }
    let mut mean = 0.0f64;
    let mut cov = [0.0f64; 4];
    let mut idx = [0usize; 4];
    for _ in 0..n {
        let mut off = 0;
        for k in 0..d {
            off += (origin[k] + idx[k]) * strides[k];
        }
        let v = data[off].to_f64();
        mean += v;
        for k in 0..d {
            cov[k] += (idx[k] as f64 - centers[k]) * v;
        }
        for k in (0..d).rev() {
            idx[k] += 1;
            if idx[k] < bsize[k] {
                break;
            }
            idx[k] = 0;
        }
    }
    mean /= n as f64;
    for k in 0..d {
        out[k + 1] = if vars[k] > 0.0 {
            cov[k] / (n as f64 * vars[k])
        } else {
            0.0
        };
    }
    out[0] = mean - (0..d).map(|k| out[k + 1] * centers[k]).sum::<f64>();
}

fn reg_tau(tau: f64, d: usize) -> f64 {
    tau / (2.0 * (d as f64 + 1.0) * EDGE as f64)
}

/// Entropy-proxy cost (bits) of a quantization code.
#[inline]
fn code_cost(code: f64) -> f64 {
    (code.abs() + 1.0).log2() + 2.0
}

impl Hybrid {
    /// Shared compress core; all large working buffers come from `ws`, the
    /// small per-block index/coefficient vectors are hoisted out of the
    /// block loop, so steady-state calls allocate O(1) times.
    fn compress_impl<T: Scalar>(
        &self,
        data: &Tensor<T>,
        tol: Tolerance,
        ws: &mut HybridScratch<T>,
    ) -> Result<Vec<u8>> {
        let tau = tol.absolute(data.value_range());
        if tau <= 0.0 {
            return Err(Error::invalid("tolerance must be positive"));
        }
        let shape = data.shape().to_vec();
        let d = shape.len();
        if d > 4 {
            return Err(Error::invalid("hybrid model supports up to 4 dimensions"));
        }
        let strides = strides_for(&shape);
        let src = data.data();
        let radius = self.cfg.radius;
        let prec = intprec::<T>();
        let rt = reg_tau(tau, d);
        let lorenzo_penalty = crate::adaptive::lorenzo_penalty_factor(d) * tau;
        let recon = &mut ws.recon;
        recon.clear();
        recon.resize(src.len(), T::ZERO);

        let nblocks: Vec<usize> = shape.iter().map(|&n| n.div_ceil(EDGE)).collect();
        let total_blocks: usize = nblocks.iter().product();
        let size = EDGE.pow(d as u32);

        let symbols = &mut ws.symbols;
        symbols.clear();
        let literals = &mut ws.literals;
        literals.clear();
        let flags = &mut ws.flags;
        flags.clear();
        flags.reserve(total_blocks);
        let reg_codes = &mut ws.reg_codes;
        reg_codes.clear();
        let mut tw = BitWriter::new(); // transform sub-stream

        let mut bidx = vec![0usize; d];
        let mut pt = vec![0usize; d];
        let block = &mut ws.block;
        block.clear();
        block.resize(size, 0.0);
        // per-block index/coefficient buffers, allocated once per call
        let mut origin = vec![0usize; d];
        let mut bsize = vec![0usize; d];
        let mut iidx = vec![0usize; d];
        let mut i = vec![0usize; d];
        let mut coeffs = vec![0.0f64; d + 1];
        let mut qcoeffs = vec![0.0f64; d + 1];
        for _ in 0..total_blocks {
            for k in 0..d {
                origin[k] = bidx[k] * EDGE;
                bsize[k] = EDGE.min(shape[k] - origin[k]);
            }
            let bn: usize = bsize.iter().product();

            // gather the block (edge replication for partial blocks)
            {
                iidx.iter_mut().for_each(|x| *x = 0);
                for item in block.iter_mut() {
                    let mut off = 0;
                    for k in 0..d {
                        let x = (origin[k] + iidx[k]).min(shape[k] - 1);
                        off += x * strides[k];
                    }
                    *item = src[off].to_f64();
                    for k in (0..d).rev() {
                        iidx[k] += 1;
                        if iidx[k] < EDGE {
                            break;
                        }
                        iidx[k] = 0;
                    }
                }
            }

            // --- candidate 1+2: prediction cost estimates ---
            fit_regression(src, &strides, &origin, &bsize, &mut coeffs);
            for (q, &c) in qcoeffs.iter_mut().zip(coeffs.iter()) {
                *q = (c / (2.0 * rt)).round() * 2.0 * rt;
            }
            let mut cost_lor = 0.0f64;
            let mut cost_reg = (d + 1) as f64 * 16.0; // coefficient overhead
            {
                i.iter_mut().for_each(|x| *x = 0);
                for _ in 0..bn {
                    let mut off = 0;
                    for k in 0..d {
                        pt[k] = origin[k] + i[k];
                        off += pt[k] * strides[k];
                    }
                    let v = src[off].to_f64();
                    let lp = lorenzo_pred(src, &pt, &strides);
                    cost_lor += code_cost(((lp - v).abs() + lorenzo_penalty) / (2.0 * tau));
                    let rp = qcoeffs[0]
                        + (0..d).map(|k| qcoeffs[k + 1] * i[k] as f64).sum::<f64>();
                    cost_reg += code_cost((rp - v).abs() / (2.0 * tau));
                    for k in (0..d).rev() {
                        i[k] += 1;
                        if i[k] < bsize[k] {
                            break;
                        }
                        i[k] = 0;
                    }
                }
            }
            // --- candidate 3: trial transform encoding (the costly step) ---
            let mut trial = BitWriter::new();
            encode_block_f64(block, d, tau, prec, &mut trial);
            let trial_bits = trial.bit_len();
            let cost_tr = trial_bits as f64;
            let trial_bytes = trial.finish();

            let mode = if cost_tr < cost_lor && cost_tr < cost_reg {
                Mode::Transform
            } else if cost_reg < cost_lor {
                Mode::Regression
            } else {
                Mode::Lorenzo
            };
            flags.push(mode as u8);

            match mode {
                Mode::Transform => {
                    // splice the trial encoding into the transform stream and
                    // set recon from its decoded values (needed by later
                    // Lorenzo predictions)
                    let mut tr = BitReader::new(&trial_bytes);
                    let dec = decode_block_f64(d, tau, prec, &mut tr)?;
                    let mut tr2 = BitReader::new(&trial_bytes);
                    for _ in 0..trial_bits {
                        tw.write_bit(tr2.read_bit().expect("trial length"));
                    }
                    iidx.iter_mut().for_each(|x| *x = 0);
                    for &v in dec.iter() {
                        let mut off = 0;
                        let mut in_domain = true;
                        for k in 0..d {
                            let x = origin[k] + iidx[k];
                            if x >= shape[k] {
                                in_domain = false;
                                break;
                            }
                            off += x * strides[k];
                        }
                        if in_domain {
                            recon[off] = T::from_f64(v);
                        }
                        for k in (0..d).rev() {
                            iidx[k] += 1;
                            if iidx[k] < EDGE {
                                break;
                            }
                            iidx[k] = 0;
                        }
                    }
                }
                Mode::Regression | Mode::Lorenzo => {
                    if mode == Mode::Regression {
                        for &c in coeffs.iter() {
                            write_i64(reg_codes, (c / (2.0 * rt)).round() as i64);
                        }
                    }
                    i.iter_mut().for_each(|x| *x = 0);
                    for _ in 0..bn {
                        let mut off = 0;
                        for k in 0..d {
                            pt[k] = origin[k] + i[k];
                            off += pt[k] * strides[k];
                        }
                        let v = src[off].to_f64();
                        let pred = if mode == Mode::Regression {
                            qcoeffs[0]
                                + (0..d).map(|k| qcoeffs[k + 1] * i[k] as f64).sum::<f64>()
                        } else {
                            lorenzo_pred(recon, &pt, &strides)
                        };
                        let code = ((v - pred) / (2.0 * tau)).round();
                        let ok = code.is_finite() && code.abs() < (radius - 1) as f64;
                        let mut stored = false;
                        if ok {
                            let rec_t = T::from_f64(pred + code * 2.0 * tau);
                            if (rec_t.to_f64() - v).abs() <= tau {
                                symbols.push((code as i64 + radius) as u32);
                                recon[off] = rec_t;
                                stored = true;
                            }
                        }
                        if !stored {
                            symbols.push(0);
                            src[off].write_le(literals);
                            recon[off] = src[off];
                        }
                        for k in (0..d).rev() {
                            i[k] += 1;
                            if i[k] < bsize[k] {
                                break;
                            }
                            i[k] = 0;
                        }
                    }
                }
            }

            for k in (0..d).rev() {
                bidx[k] += 1;
                if bidx[k] < nblocks[k] {
                    break;
                }
                bidx[k] = 0;
            }
        }

        let mut payload = Vec::new();
        write_section(&mut payload, flags);
        write_section(&mut payload, reg_codes);
        write_section(&mut payload, &huffman_encode(symbols));
        write_section(&mut payload, literals);
        write_section(&mut payload, &tw.finish());
        let compressed = lossless_compress(&payload, self.cfg.zstd_level)?;

        let mut out = Vec::with_capacity(compressed.len() + 64);
        Header {
            method: Method::Hybrid,
            dtype: T::DTYPE_TAG,
            shape,
            tau_abs: tau,
        }
        .write(&mut out);
        write_u64(&mut out, payload.len() as u64);
        out.extend_from_slice(&compressed);
        Ok(out)
    }
}

impl<T: Scalar> Compressor<T> for Hybrid {
    fn name(&self) -> &'static str {
        "HybridModel"
    }

    fn compress(&self, data: &Tensor<T>, tol: Tolerance) -> Result<Vec<u8>> {
        self.compress_impl(data, tol, &mut HybridScratch::default())
    }

    fn compress_scratch(
        &self,
        data: &Tensor<T>,
        tol: Tolerance,
        scratch: &mut CodecScratch<T>,
    ) -> Result<Vec<u8>> {
        self.compress_impl(data, tol, &mut scratch.hybrid)
    }

    fn decompress(&self, bytes: &[u8]) -> Result<Tensor<T>> {
        let (header, mut r) = Header::read(bytes)?;
        header.expect::<T>(Method::Hybrid)?;
        let tau = header.tau_abs;
        let shape = header.shape.clone();
        let d = shape.len();
        let strides = strides_for(&shape);
        let n: usize = shape.iter().product();
        let prec = intprec::<T>();
        let rt = reg_tau(tau, d);
        let radius = self.cfg.radius;

        let payload_len = r.usize()?;
        let payload = lossless_decompress(r.bytes(r.remaining())?, payload_len)?;
        let mut pr = ByteReader::new(&payload);
        let flags = pr.section()?.to_vec();
        let reg_codes_raw = pr.section()?.to_vec();
        let symbols = huffman_decode(pr.section()?)?;
        let literals = pr.section()?.to_vec();
        let transform_stream = pr.section()?.to_vec();

        let nblocks: Vec<usize> = shape.iter().map(|&s| s.div_ceil(EDGE)).collect();
        let total_blocks: usize = nblocks.iter().product();
        if flags.len() != total_blocks {
            return Err(Error::corrupt("hybrid flag stream size mismatch"));
        }
        let mut recon = vec![T::ZERO; n];
        let mut reg_reader = ByteReader::new(&reg_codes_raw);
        let mut tr = BitReader::new(&transform_stream);
        let mut sym_pos = 0usize;
        let mut lit_pos = 0usize;
        let mut bidx = vec![0usize; d];
        let mut pt = vec![0usize; d];
        for b in 0..total_blocks {
            let origin: Vec<usize> = (0..d).map(|k| bidx[k] * EDGE).collect();
            let bsize: Vec<usize> = (0..d).map(|k| EDGE.min(shape[k] - origin[k])).collect();
            let bn: usize = bsize.iter().product();
            match Mode::from_u8(flags[b])? {
                Mode::Transform => {
                    let dec = decode_block_f64(d, tau, prec, &mut tr)?;
                    let mut iidx = vec![0usize; d];
                    for &v in dec.iter() {
                        let mut off = 0;
                        let mut in_domain = true;
                        for k in 0..d {
                            let x = origin[k] + iidx[k];
                            if x >= shape[k] {
                                in_domain = false;
                                break;
                            }
                            off += x * strides[k];
                        }
                        if in_domain {
                            recon[off] = T::from_f64(v);
                        }
                        for k in (0..d).rev() {
                            iidx[k] += 1;
                            if iidx[k] < EDGE {
                                break;
                            }
                            iidx[k] = 0;
                        }
                    }
                }
                mode => {
                    let mut qcoeffs = vec![0.0f64; d + 1];
                    if mode == Mode::Regression {
                        for qc in qcoeffs.iter_mut() {
                            *qc = reg_reader.i64()? as f64 * 2.0 * rt;
                        }
                    }
                    let mut i = vec![0usize; d];
                    for _ in 0..bn {
                        let mut off = 0;
                        for k in 0..d {
                            pt[k] = origin[k] + i[k];
                            off += pt[k] * strides[k];
                        }
                        if sym_pos >= symbols.len() {
                            return Err(Error::corrupt("hybrid symbol stream exhausted"));
                        }
                        let s = symbols[sym_pos];
                        sym_pos += 1;
                        if s == 0 {
                            if lit_pos + T::BYTES > literals.len() {
                                return Err(Error::corrupt("hybrid literal stream exhausted"));
                            }
                            recon[off] = T::read_le(&literals[lit_pos..]);
                            lit_pos += T::BYTES;
                        } else {
                            let code = s as i64 - radius;
                            let pred = if mode == Mode::Regression {
                                qcoeffs[0]
                                    + (0..d)
                                        .map(|k| qcoeffs[k + 1] * i[k] as f64)
                                        .sum::<f64>()
                            } else {
                                lorenzo_pred(&recon, &pt, &strides)
                            };
                            recon[off] = T::from_f64(pred + code as f64 * 2.0 * tau);
                        }
                        for k in (0..d).rev() {
                            i[k] += 1;
                            if i[k] < bsize[k] {
                                break;
                            }
                            i[k] = 0;
                        }
                    }
                }
            }
            for k in (0..d).rev() {
                bidx[k] += 1;
                if bidx[k] < nblocks[k] {
                    break;
                }
                bidx[k] = 0;
            }
        }
        Tensor::from_vec(&shape, recon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::metrics::linf_error;

    fn check_bound<T: Scalar>(t: &Tensor<T>, tau: f64) -> usize {
        let h = Hybrid::default();
        let bytes = h.compress(t, Tolerance::Abs(tau)).unwrap();
        let back: Tensor<T> = h.decompress(&bytes).unwrap();
        let err = linf_error(t.data(), back.data());
        assert!(err <= tau * (1.0 + 1e-9), "L∞ {err} > τ {tau}");
        bytes.len()
    }

    #[test]
    fn smooth_3d_bounded() {
        let t = crate::data::synth::smooth_test_field(&[20, 20, 20]);
        let size = check_bound(&t, 1e-3);
        assert!(size < t.nbytes() / 3);
    }

    #[test]
    fn oscillatory_data_uses_transform_blocks() {
        // high-frequency oscillation is where the transform should win
        let t = Tensor::<f32>::from_fn(&[16, 16, 16], |ix| {
            ((ix[0] as f32) * 2.1).sin() * ((ix[1] as f32) * 1.9).cos()
                * ((ix[2] as f32) * 2.3).sin()
        });
        let h = Hybrid::default();
        let bytes = h.compress(&t, Tolerance::Rel(1e-3)).unwrap();
        let back: Tensor<f32> = h.decompress(&bytes).unwrap();
        let tau = 1e-3 * t.value_range();
        assert!(linf_error(t.data(), back.data()) <= tau * (1.0 + 1e-9));
    }

    #[test]
    fn random_data_bounded() {
        let mut rng = Rng::new(11);
        let t = Tensor::<f32>::from_fn(&[13, 10], |_| rng.uniform_in(-1.0, 1.0) as f32);
        check_bound(&t, 0.02);
    }

    #[test]
    fn dims_1_through_4() {
        for shape in [vec![30usize], vec![9, 11], vec![6, 7, 8], vec![5, 5, 5, 5]] {
            let t = Tensor::<f32>::from_fn(&shape, |ix| {
                (ix.iter().sum::<usize>() as f32 * 0.4).cos()
            });
            check_bound(&t, 1e-3);
        }
    }

    #[test]
    fn f64_support() {
        let t = Tensor::<f64>::from_fn(&[9, 9, 9], |ix| {
            ((ix[0] + 2 * ix[1]) as f64 * 0.21).sin() * 0.01
        });
        check_bound(&t, 1e-7);
    }
}
