//! Per-worker codec workspace.
//!
//! [`CodecScratch`] bundles every reusable buffer the compressors need —
//! the contiguous engine's [`DecomposeScratch`], the fused quantizer
//! stream pool, the staged per-level coefficient pool, and the hybrid
//! model's reconstruction/stream buffers — so one allocation-warm
//! workspace can be threaded through an arbitrary number of
//! [`super::Compressor::compress_scratch`] calls.
//!
//! The chunk worker pool ([`crate::chunk`]) and the streaming pipeline
//! ([`crate::stream`]) create **one scratch per worker thread** and pass
//! it to every block that worker compresses; after the first few blocks
//! warm the buffers to their high-water mark, steady-state compression
//! performs O(1) heap allocations per block (enforced by
//! `rust/tests/alloc_budget.rs`).
//!
//! # Invariants
//!
//! * Reuse is value-transparent: compressing through a reused scratch
//!   yields bytes identical to a fresh one (differential-tested).
//! * A scratch carries no inter-call data dependencies, only capacity (and
//!   Thomas factorizations, which are pure functions of line length).
//! * A scratch is single-threaded state: one per worker, never shared.

use crate::decompose::fused::FusedStreams;
use crate::decompose::DecomposeScratch;
use crate::quant::QuantStream;
use crate::tensor::Scalar;

/// Reusable buffers of the hybrid model's block loop.
pub(crate) struct HybridScratch<T: Scalar> {
    /// Running reconstruction (later Lorenzo predictions read it).
    pub(crate) recon: Vec<T>,
    /// Quantization symbols of the prediction modes.
    pub(crate) symbols: Vec<u32>,
    /// Escaped literal values.
    pub(crate) literals: Vec<u8>,
    /// Per-block mode flags.
    pub(crate) flags: Vec<u8>,
    /// Quantized regression coefficients.
    pub(crate) reg_codes: Vec<u8>,
    /// Gathered 4^d block values.
    pub(crate) block: Vec<f64>,
}

// manual `Default` impls: a derive would add a spurious `T: Default` bound
// the generic `T: Scalar` call sites (chunk/stream workers) cannot meet
impl<T: Scalar> Default for HybridScratch<T> {
    fn default() -> Self {
        HybridScratch {
            recon: Vec::new(),
            symbols: Vec::new(),
            literals: Vec::new(),
            flags: Vec::new(),
            reg_codes: Vec::new(),
            block: Vec::new(),
        }
    }
}

/// Reusable workspace for [`super::Compressor::compress_scratch`].
///
/// See the module docs for the reuse contract. Constructing one is cheap
/// (all buffers start empty); the win comes from passing the *same*
/// scratch to many calls.
pub struct CodecScratch<T: Scalar> {
    /// Contiguous-engine workspace (sweeps, corrections, compactions).
    ///
    /// Public so callers (and the differential test-suite) can tune
    /// [`DecomposeScratch::panel_width`] before compressing; the width is
    /// value-transparent — any setting produces bit-identical output —
    /// so exposing it cannot break the reuse contract above.
    pub decompose: DecomposeScratch<T>,
    /// Fused-path per-level + merged quantizer streams.
    pub(crate) fused: FusedStreams,
    /// Staged-path per-level coefficient stream pool (adaptive mode).
    pub(crate) streams: Vec<Vec<T>>,
    /// Staged-path merged symbol/escape stream.
    pub(crate) qs: QuantStream,
    /// Hybrid-model buffers.
    pub(crate) hybrid: HybridScratch<T>,
}

impl<T: Scalar> Default for CodecScratch<T> {
    fn default() -> Self {
        CodecScratch {
            decompose: DecomposeScratch::default(),
            fused: FusedStreams::default(),
            streams: Vec::new(),
            qs: QuantStream::default(),
            hybrid: HybridScratch::default(),
        }
    }
}

impl<T: Scalar> CodecScratch<T> {
    /// Fresh, empty workspace.
    pub fn new() -> Self {
        CodecScratch::default()
    }
}
