//! ZFP-style transform-based error-bounded compressor ([3], fixed-accuracy
//! mode), the paper's fastest baseline.
//!
//! Faithful-shape reimplementation: data is partitioned into 4ᵈ blocks;
//! each block is aligned to a common exponent (block-floating-point),
//! converted to fixed point, decorrelated by zfp's non-orthogonal lifting
//! transform along each dimension, mapped to negabinary, and coded bit-plane
//! by bit-plane with embedded group testing. Accuracy mode discards planes
//! below the tolerance-derived cutoff.

use super::format::{Header, Method};
use super::{Compressor, Tolerance};
use crate::encode::{BitReader, BitWriter};
use crate::encode::{lossless_compress, lossless_decompress};
use crate::encode::varint::write_u64;
use crate::error::{Error, Result};
use crate::tensor::{strides_for, Scalar, Tensor};

/// ZFP configuration.
#[derive(Clone, Copy, Debug)]
pub struct ZfpConfig {
    /// Lossless effort level applied to the bitstream (zfp itself skips this, but the
    /// paper's pipelines all end in a lossless stage; level 1 keeps the
    /// throughput character).
    pub zstd_level: i32,
}

impl Default for ZfpConfig {
    fn default() -> Self {
        ZfpConfig { zstd_level: 1 }
    }
}

/// The ZFP compressor.
#[derive(Clone, Debug, Default)]
pub struct Zfp {
    cfg: ZfpConfig,
}

impl Zfp {
    /// Build with an explicit configuration.
    pub fn new(cfg: ZfpConfig) -> Self {
        Zfp { cfg }
    }
}

const EDGE: usize = 4;
const NBMASK: u64 = 0xaaaa_aaaa_aaaa_aaaa;

/// Fixed-point precision: 30 value bits + 2 guard bits for f32-class data,
/// wider for f64 (transform growth stays within i64).
pub(crate) fn intprec<T: Scalar>() -> u32 {
    if T::BYTES == 4 {
        32
    } else {
        56
    }
}

/// zfp forward lifting transform on 4 elements at stride `s`.
#[inline]
fn fwd_lift(p: &mut [i64], base: usize, s: usize) {
    let (mut x, mut y, mut z, mut w) = (p[base], p[base + s], p[base + 2 * s], p[base + 3 * s]);
    x += w;
    x >>= 1;
    w -= x;
    z += y;
    z >>= 1;
    y -= z;
    x += z;
    x >>= 1;
    z -= x;
    w += y;
    w >>= 1;
    y -= w;
    w += y >> 1;
    y -= w >> 1;
    p[base] = x;
    p[base + s] = y;
    p[base + 2 * s] = z;
    p[base + 3 * s] = w;
}

/// zfp inverse lifting transform: reverses each [`fwd_lift`] step. The `>>1`
/// steps of the forward pass drop a low bit, so the pair round-trips to
/// within 2 fixed-point ULPs (absorbed by the 2·(d+1)-bit precision guard),
/// exactly like the reference implementation.
#[inline]
fn inv_lift(p: &mut [i64], base: usize, s: usize) {
    let (mut x, mut y, mut z, mut w) = (p[base], p[base + s], p[base + 2 * s], p[base + 3 * s]);
    y += w >> 1;
    w -= y >> 1;
    y += w;
    w <<= 1;
    w -= y;
    z += x;
    x <<= 1;
    x -= z;
    y += z;
    z <<= 1;
    z -= y;
    w += x;
    x <<= 1;
    x -= w;
    p[base] = x;
    p[base + s] = y;
    p[base + 2 * s] = z;
    p[base + 3 * s] = w;
}

/// Total-sequency permutation of block coefficients (low-frequency first).
fn sequency_perm(d: usize) -> Vec<usize> {
    let size = EDGE.pow(d as u32);
    let mut idx: Vec<usize> = (0..size).collect();
    let digitsum = |mut i: usize| {
        let mut s = 0;
        for _ in 0..d {
            s += i % EDGE;
            i /= EDGE;
        }
        s
    };
    idx.sort_by_key(|&i| (digitsum(i), i));
    idx
}

#[inline]
fn int_to_negabinary(v: i64) -> u64 {
    ((v as u64).wrapping_add(NBMASK)) ^ NBMASK
}

#[inline]
fn negabinary_to_int(u: u64) -> i64 {
    (u ^ NBMASK).wrapping_sub(NBMASK) as i64
}

/// 256-bit plane bitset (4-D blocks have 256 coefficients).
#[derive(Clone, Copy, Default)]
struct Plane([u64; 4]);

impl Plane {
    #[inline]
    fn set(&mut self, i: usize) {
        self.0[i >> 6] |= 1u64 << (i & 63);
    }
    #[inline]
    fn get(&self, i: usize) -> bool {
        self.0[i >> 6] >> (i & 63) & 1 == 1
    }
    /// First set bit at position >= i, if any (up to `size`).
    fn first_set_from(&self, i: usize, size: usize) -> Option<usize> {
        let mut word = i >> 6;
        let mut mask = !0u64 << (i & 63);
        while word < 4 {
            let bits = self.0[word] & mask;
            if bits != 0 {
                let j = (word << 6) + bits.trailing_zeros() as usize;
                return if j < size { Some(j) } else { None };
            }
            word += 1;
            mask = !0;
        }
        None
    }
}

/// Embedded encoding of one block's negabinary coefficients.
fn encode_block_planes(neg: &[u64], size: usize, kmin: u32, prec: u32, w: &mut BitWriter) {
    let mut n = 0usize;
    for k in (kmin..prec).rev() {
        let mut plane = Plane::default();
        for (i, &v) in neg.iter().enumerate() {
            if v >> k & 1 == 1 {
                plane.set(i);
            }
        }
        // verbatim bits for already-significant coefficients
        for i in 0..n {
            w.write_bit(plane.get(i));
        }
        let mut i = n;
        while i < size {
            match plane.first_set_from(i, size) {
                None => {
                    w.write_bit(false);
                    break;
                }
                Some(j) => {
                    w.write_bit(true);
                    while i < j {
                        w.write_bit(false);
                        i += 1;
                    }
                    if j == size - 1 {
                        i = size; // implied by the group test
                    } else {
                        w.write_bit(true);
                        i = j + 1;
                    }
                }
            }
        }
        n = n.max(i);
    }
}

/// Inverse of [`encode_block_planes`].
fn decode_block_planes(
    size: usize,
    kmin: u32,
    prec: u32,
    r: &mut BitReader,
) -> Result<Vec<u64>> {
    let mut neg = vec![0u64; size];
    let mut n = 0usize;
    let err = || Error::corrupt("zfp bitstream truncated");
    for k in (kmin..prec).rev() {
        for item in neg.iter_mut().take(n) {
            if r.read_bit().ok_or_else(err)? {
                *item |= 1u64 << k;
            }
        }
        let mut i = n;
        while i < size {
            let any = r.read_bit().ok_or_else(err)?;
            if !any {
                break;
            }
            loop {
                if i == size - 1 {
                    neg[i] |= 1u64 << k;
                    i = size;
                    break;
                }
                let b = r.read_bit().ok_or_else(err)?;
                if b {
                    neg[i] |= 1u64 << k;
                    i += 1;
                    break;
                }
                i += 1;
            }
        }
        n = n.max(i);
    }
    Ok(neg)
}

/// Encode one 4^d block of f64 values at tolerance `tau` (flag bit, emax,
/// transform, embedded planes). Shared by [`Zfp`] and the hybrid model's
/// transform predictor.
pub(crate) fn encode_block_f64(
    block: &[f64],
    d: usize,
    tau: f64,
    prec: u32,
    w: &mut BitWriter,
) {
    let size = EDGE.pow(d as u32);
    debug_assert_eq!(block.len(), size);
    let bstrides: Vec<usize> = (0..d).map(|k| EDGE.pow((d - 1 - k) as u32)).collect();
    let minexp = tau.log2().floor() as i32;
    let maxabs = block.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
    if maxabs == 0.0 || !maxabs.is_finite() {
        w.write_bit(false);
        return;
    }
    w.write_bit(true);
    let emax = exponent(maxabs);
    w.write_bits((emax + 16384) as u64, 15);
    let scale = (2f64).powi(prec as i32 - 2 - emax);
    let mut ints: Vec<i64> = block.iter().map(|&v| (v * scale) as i64).collect();
    for k in 0..d {
        let s = bstrides[k];
        for base in line_bases(d, k, &bstrides) {
            fwd_lift(&mut ints, base, s);
        }
    }
    let perm = sequency_perm(d);
    let neg: Vec<u64> = perm.iter().map(|&i| int_to_negabinary(ints[i])).collect();
    let maxprec = (emax - minexp + 2 * (d as i32 + 1)).clamp(0, prec as i32) as u32;
    let kmin = prec - maxprec;
    encode_block_planes(&neg, size, kmin, prec, w);
}

/// Inverse of [`encode_block_f64`].
pub(crate) fn decode_block_f64(
    d: usize,
    tau: f64,
    prec: u32,
    r: &mut BitReader,
) -> Result<Vec<f64>> {
    let size = EDGE.pow(d as u32);
    let bstrides: Vec<usize> = (0..d).map(|k| EDGE.pow((d - 1 - k) as u32)).collect();
    let minexp = tau.log2().floor() as i32;
    let nonzero = r
        .read_bit()
        .ok_or_else(|| Error::corrupt("zfp block stream truncated (flag)"))?;
    if !nonzero {
        return Ok(vec![0.0; size]);
    }
    let emax = r
        .read_bits(15)
        .ok_or_else(|| Error::corrupt("zfp block stream truncated (emax)"))? as i32
        - 16384;
    let maxprec = (emax - minexp + 2 * (d as i32 + 1)).clamp(0, prec as i32) as u32;
    let kmin = prec - maxprec;
    let negv = decode_block_planes(size, kmin, prec, r)?;
    let perm = sequency_perm(d);
    let mut ints = vec![0i64; size];
    for (i, &p) in perm.iter().enumerate() {
        ints[p] = negabinary_to_int(negv[i]);
    }
    for k in (0..d).rev() {
        let s = bstrides[k];
        for base in line_bases(d, k, &bstrides) {
            inv_lift(&mut ints, base, s);
        }
    }
    let scale = (2f64).powi(-(prec as i32 - 2 - emax));
    Ok(ints.iter().map(|&v| v as f64 * scale).collect())
}

/// Exponent of the largest magnitude: smallest e with `maxabs < 2^e`.
fn exponent(maxabs: f64) -> i32 {
    debug_assert!(maxabs > 0.0);
    let mut e = maxabs.log2().floor() as i32 + 1;
    // guard against log2 rounding at power-of-two boundaries
    while maxabs >= (2f64).powi(e) {
        e += 1;
    }
    while e > i32::MIN + 1 && maxabs < (2f64).powi(e - 1) {
        e -= 1;
    }
    e
}

impl<T: Scalar> Compressor<T> for Zfp {
    fn name(&self) -> &'static str {
        "ZFP"
    }

    fn compress(&self, data: &Tensor<T>, tol: Tolerance) -> Result<Vec<u8>> {
        let tau = tol.absolute(data.value_range());
        if tau <= 0.0 {
            return Err(Error::invalid("tolerance must be positive"));
        }
        let shape = data.shape().to_vec();
        let d = shape.len();
        if d > 4 {
            return Err(Error::invalid("ZFP supports up to 4 dimensions"));
        }
        let strides = strides_for(&shape);
        let src = data.data();
        let prec = intprec::<T>();
        let size = EDGE.pow(d as u32);

        let nblocks: Vec<usize> = shape.iter().map(|&n| n.div_ceil(EDGE)).collect();
        let total_blocks: usize = nblocks.iter().product();
        let mut w = BitWriter::new();
        let mut block = vec![0f64; size];
        let mut bidx = vec![0usize; d];
        for _ in 0..total_blocks {
            // gather block with edge-replication padding for partial blocks
            let mut iidx = vec![0usize; d];
            for item in block.iter_mut() {
                let mut off = 0;
                for k in 0..d {
                    let x = (bidx[k] * EDGE + iidx[k]).min(shape[k] - 1);
                    off += x * strides[k];
                }
                *item = src[off].to_f64();
                for k in (0..d).rev() {
                    iidx[k] += 1;
                    if iidx[k] < EDGE {
                        break;
                    }
                    iidx[k] = 0;
                }
            }
            encode_block_f64(&block, d, tau, prec, &mut w);
            for k in (0..d).rev() {
                bidx[k] += 1;
                if bidx[k] < nblocks[k] {
                    break;
                }
                bidx[k] = 0;
            }
        }

        let payload = w.finish();
        let compressed = lossless_compress(&payload, self.cfg.zstd_level)?;
        let mut out = Vec::with_capacity(compressed.len() + 64);
        Header {
            method: Method::Zfp,
            dtype: T::DTYPE_TAG,
            shape,
            tau_abs: tau,
        }
        .write(&mut out);
        write_u64(&mut out, payload.len() as u64);
        out.extend_from_slice(&compressed);
        Ok(out)
    }

    fn decompress(&self, bytes: &[u8]) -> Result<Tensor<T>> {
        let (header, mut r) = Header::read(bytes)?;
        header.expect::<T>(Method::Zfp)?;
        let shape = header.shape.clone();
        let d = shape.len();
        let strides = strides_for(&shape);
        let tau = header.tau_abs;
        let prec = intprec::<T>();

        let payload_len = r.usize()?;
        let payload = lossless_decompress(r.bytes(r.remaining())?, payload_len)?;
        let mut br = BitReader::new(&payload);

        let n: usize = shape.iter().product();
        let mut out = vec![T::ZERO; n];
        let nblocks: Vec<usize> = shape.iter().map(|&s| s.div_ceil(EDGE)).collect();
        let total_blocks: usize = nblocks.iter().product();
        let mut bidx = vec![0usize; d];
        for _ in 0..total_blocks {
            let block = decode_block_f64(d, tau, prec, &mut br)?;
            // scatter in-domain values
            let mut iidx = vec![0usize; d];
            for item in block.iter() {
                let mut off = 0;
                let mut in_domain = true;
                for k in 0..d {
                    let x = bidx[k] * EDGE + iidx[k];
                    if x >= shape[k] {
                        in_domain = false;
                        break;
                    }
                    off += x * strides[k];
                }
                if in_domain {
                    out[off] = T::from_f64(*item);
                }
                for k in (0..d).rev() {
                    iidx[k] += 1;
                    if iidx[k] < EDGE {
                        break;
                    }
                    iidx[k] = 0;
                }
            }
            for k in (0..d).rev() {
                bidx[k] += 1;
                if bidx[k] < nblocks[k] {
                    break;
                }
                bidx[k] = 0;
            }
        }
        Tensor::from_vec(&shape, out)
    }
}

/// Base offsets of all 4-element lines along `dim` within a 4^d block.
fn line_bases(d: usize, dim: usize, bstrides: &[usize]) -> Vec<usize> {
    let mut bases = vec![0usize];
    for k in 0..d {
        if k == dim {
            continue;
        }
        let mut next = Vec::with_capacity(bases.len() * EDGE);
        for &b in &bases {
            for i in 0..EDGE {
                next.push(b + i * bstrides[k]);
            }
        }
        bases = next;
    }
    bases
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::metrics::linf_error;

    #[test]
    fn lift_round_trip_within_rounding() {
        // the lifting pair loses at most 2 fixed-point ULPs (see inv_lift docs)
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            let orig: Vec<i64> = (0..4).map(|_| rng.uniform_in(-1e9, 1e9) as i64).collect();
            let mut p = orig.clone();
            fwd_lift(&mut p, 0, 1);
            inv_lift(&mut p, 0, 1);
            for (a, b) in p.iter().zip(&orig) {
                assert!((a - b).abs() <= 2, "{p:?} vs {orig:?}");
            }
        }
    }

    #[test]
    fn negabinary_round_trip() {
        for v in [0i64, 1, -1, 12345, -98765, i32::MAX as i64, i32::MIN as i64] {
            assert_eq!(negabinary_to_int(int_to_negabinary(v)), v);
        }
    }

    #[test]
    fn plane_coder_self_consistent() {
        let mut rng = Rng::new(9);
        for d in 1..=4usize {
            let size = EDGE.pow(d as u32);
            let neg: Vec<u64> = (0..size)
                .map(|_| rng.next_u64() & 0xffff_ffff)
                .collect();
            let mut w = BitWriter::new();
            encode_block_planes(&neg, size, 0, 32, &mut w);
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            let back = decode_block_planes(size, 0, 32, &mut r).unwrap();
            assert_eq!(back, neg, "d={d}");
        }
    }

    #[test]
    fn plane_coder_truncated_planes() {
        // with kmin > 0, only the top planes survive
        let neg = vec![0b1111_0000u64; 16];
        let mut w = BitWriter::new();
        encode_block_planes(&neg, 16, 4, 8, &mut w);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        let back = decode_block_planes(16, 4, 8, &mut r).unwrap();
        assert_eq!(back, neg);
    }

    #[test]
    fn exponent_helper() {
        assert_eq!(exponent(1.0), 1); // 1.0 = 0.5 * 2^1
        assert_eq!(exponent(0.99), 0);
        assert_eq!(exponent(2.0), 2);
        assert_eq!(exponent(0.25), -1);
    }

    fn check_bound<T: Scalar>(t: &Tensor<T>, tau: f64) -> usize {
        let z = Zfp::default();
        let bytes = z.compress(t, Tolerance::Abs(tau)).unwrap();
        let back: Tensor<T> = z.decompress(&bytes).unwrap();
        let err = linf_error(t.data(), back.data());
        assert!(err <= tau, "L∞ {err} > τ {tau}");
        bytes.len()
    }

    #[test]
    fn smooth_3d_bound_and_ratio() {
        let t = crate::data::synth::smooth_test_field(&[20, 20, 20]);
        let size = check_bound(&t, 1e-3);
        assert!(size < t.nbytes() / 3, "{size} vs {}", t.nbytes());
    }

    #[test]
    fn random_data_bounded() {
        let mut rng = Rng::new(3);
        let t = Tensor::<f32>::from_fn(&[11, 13], |_| rng.uniform_in(-2.0, 2.0) as f32);
        check_bound(&t, 0.01);
    }

    #[test]
    fn dims_1_through_4() {
        for shape in [vec![40usize], vec![9, 11], vec![6, 7, 8], vec![5, 5, 5, 5]] {
            let t = Tensor::<f32>::from_fn(&shape, |ix| {
                (ix.iter().sum::<usize>() as f32 * 0.37).sin()
            });
            check_bound(&t, 1e-3);
        }
    }

    #[test]
    fn f64_tight_tolerance() {
        let t = Tensor::<f64>::from_fn(&[9, 9, 9], |ix| {
            ((ix[0] as f64) * 0.3).sin() + (ix[1] as f64 * ix[2] as f64) * 1e-4
        });
        check_bound(&t, 1e-9);
    }

    #[test]
    fn zero_field_compresses_to_flags() {
        let t = Tensor::<f32>::zeros(&[16, 16, 16]);
        let z = Zfp::default();
        let bytes = z.compress(&t, Tolerance::Abs(1e-3)).unwrap();
        let back: Tensor<f32> = z.decompress(&bytes).unwrap();
        assert_eq!(back.data(), t.data());
        assert!(bytes.len() < 200, "zero field should be ~1 bit/block: {}", bytes.len());
    }

    #[test]
    fn huge_dynamic_range() {
        let mut rng = Rng::new(7);
        let t = Tensor::<f32>::from_fn(&[12, 12, 12], |_| {
            ((rng.uniform_in(-8.0, 8.0) as f32).exp()) * 1e3
        });
        let tau = t.value_range() * 1e-3;
        check_bound(&t, tau);
    }
}
