//! MGARD+ (Algorithm 1): multilevel data reduction with level-wise
//! quantization (§4.1) and adaptive decomposition (§4.2).
//!
//! Decomposition proceeds level by level; before each step the §4.2.3
//! sampling estimate compares the (penalty-adjusted) Lorenzo predictor
//! against piecewise multilinear interpolation, and when Lorenzo wins the
//! remaining coarse representation is handed to an *external* error-bounded
//! compressor. Coefficients of level `l` are quantized with the κ-scaled
//! tolerance `τ_l`, entropy-coded (Huffman) and LZ-compressed.
//!
//! The paper's future-work extension — swapping the external compressor for
//! ZFP or the hybrid model (§6.3.2) — is implemented via
//! [`ExternalChoice`].

use super::format::{Header, Method};
use super::{CodecScratch, Compressor, Hybrid, Sz, Tolerance, Zfp};
use crate::adaptive::estimate_predictors;
use crate::decompose::{contiguous, fused, Decomposer, Decomposition, OptFlags};
use crate::encode::varint::{write_section, write_u64, ByteReader};
use crate::encode::{huffman_decode, huffman_encode, lossless_compress, lossless_decompress};
use crate::error::{Error, Result};
use crate::grid::Hierarchy;
use crate::quant::{dequantize, kappa, level_tolerances, quantize, QuantStream, DEFAULT_C_LINF};
use crate::tensor::{Scalar, Tensor};

/// Which external compressor handles the coarse representation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExternalChoice {
    /// SZ (the paper's choice: best ratio at fixed tolerance, complementary
    /// Lorenzo predictor).
    Sz = 0,
    /// ZFP (paper §6.3.2 future work; wins on oscillatory data like QMCPACK).
    Zfp = 1,
    /// The hybrid model (future work; slowest, best ratio on some data).
    Hybrid = 2,
}

impl ExternalChoice {
    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => ExternalChoice::Sz,
            1 => ExternalChoice::Zfp,
            2 => ExternalChoice::Hybrid,
            other => return Err(Error::corrupt(format!("external compressor tag {other}"))),
        })
    }

    fn compress<T: Scalar>(&self, data: &Tensor<T>, tau_abs: f64) -> Result<Vec<u8>> {
        let tol = Tolerance::Abs(tau_abs);
        match self {
            ExternalChoice::Sz => Sz::default().compress(data, tol),
            ExternalChoice::Zfp => Zfp::default().compress(data, tol),
            ExternalChoice::Hybrid => Hybrid::default().compress(data, tol),
        }
    }

    fn decompress<T: Scalar>(&self, bytes: &[u8]) -> Result<Tensor<T>> {
        match self {
            ExternalChoice::Sz => Sz::default().decompress(bytes),
            ExternalChoice::Zfp => Zfp::default().decompress(bytes),
            ExternalChoice::Hybrid => Hybrid::default().decompress(bytes),
        }
    }
}

/// MGARD+ configuration.
#[derive(Clone, Copy, Debug)]
pub struct MgardPlusConfig {
    /// §4.1 level-wise quantization (off = uniform split, for the Fig. 10
    /// "AD" ablation line).
    pub levelwise: bool,
    /// §4.2 adaptive termination (off = always decompose fully, for the
    /// Fig. 10 "LQ" ablation line).
    pub adaptive: bool,
    /// External compressor for the coarse representation.
    pub external: ExternalChoice,
    /// L∞ constant distributing the error budget.
    pub c_linf: f64,
    /// Block-sampling stride of the §4.2.3 estimate (paper: 1 in 4).
    pub sample_stride: usize,
    /// Cap on decomposition depth.
    pub max_levels: Option<usize>,
    /// Lossless-stage effort level (kept as `zstd_level` for config compatibility).
    pub zstd_level: i32,
    /// Engine optimization flags (all on = MGARD+; exposed for ablations).
    pub flags: OptFlags,
}

impl Default for MgardPlusConfig {
    fn default() -> Self {
        MgardPlusConfig {
            levelwise: true,
            adaptive: true,
            external: ExternalChoice::Sz,
            c_linf: DEFAULT_C_LINF,
            sample_stride: 4,
            max_levels: None,
            zstd_level: 3,
            flags: OptFlags::all(),
        }
    }
}

impl MgardPlusConfig {
    /// Fig. 10 "LQ" ablation: level-wise quantization only.
    pub fn lq_only() -> Self {
        MgardPlusConfig {
            adaptive: false,
            ..Self::default()
        }
    }

    /// Fig. 10 "AD" ablation: adaptive decomposition only.
    pub fn ad_only() -> Self {
        MgardPlusConfig {
            levelwise: false,
            ..Self::default()
        }
    }
}

/// The MGARD+ compressor (Algorithm 1).
#[derive(Clone, Debug, Default)]
pub struct MgardPlus {
    cfg: MgardPlusConfig,
}

impl MgardPlus {
    /// Build with an explicit configuration.
    pub fn new(cfg: MgardPlusConfig) -> Self {
        MgardPlus { cfg }
    }

    /// Wrap into a block-parallel compressor (see [`crate::chunk`]): the
    /// field is tiled by `cfg.block_shape` and each block runs the full
    /// MGARD+ path on the worker pool, preserving the global L∞ bound.
    /// For fields larger than RAM, the same block pipeline can be fed from
    /// disk under a memory budget via [`crate::stream::compress_to_writer`]
    /// with a [`crate::stream::RawFileSource`]; the container is
    /// byte-identical either way.
    pub fn chunked(
        self,
        cfg: crate::chunk::ChunkedConfig,
    ) -> crate::chunk::ChunkedCompressor<Self> {
        crate::chunk::ChunkedCompressor::new(self, cfg)
    }

    /// Tolerance tiers for levels `l̃ ..= L` (index 0 = coarse).
    fn tiers(&self, levels: usize, d: usize, tau: f64) -> Vec<f64> {
        if self.cfg.levelwise {
            level_tolerances(levels, d, tau, self.cfg.c_linf)
        } else {
            vec![tau / (self.cfg.c_linf * levels as f64); levels]
        }
    }
}

/// Decomposition schedule recorded in an MGARD+ container.
///
/// The schedule is a property of the *configuration* (`cfg.adaptive`), not
/// of the execution path: the fused and staged engines produce bit-identical
/// containers for the same schedule, so recording "fused vs staged" would be
/// meaningless (and would break that differential invariant). What varies —
/// and what `info` reports — is whether the level schedule was fixed up
/// front (fused-eligible) or chosen adaptively at runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// §4.2 adaptive termination was on: the stop level was chosen at
    /// runtime, so only the staged engine could have produced the bytes.
    Adaptive,
    /// The level schedule was static (adaptive off): the container is
    /// fused-eligible — the single-pass and staged engines both produce
    /// exactly these bytes.
    Static,
}

impl std::fmt::Display for Schedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Schedule::Adaptive => "adaptive (staged engine)",
            Schedule::Static => "static (fused-eligible)",
        })
    }
}

/// Assemble the MGARD+ container (shared by the decomposed and the
/// direct-external paths).
fn finish_container<T: Scalar>(
    shape: &[usize],
    tau: f64,
    cfg: &MgardPlusConfig,
    stop: usize,
    external_bytes: &[u8],
    qs: &QuantStream,
) -> Result<Vec<u8>> {
    let mut payload = Vec::new();
    write_u64(&mut payload, stop as u64);
    write_u64(&mut payload, cfg.max_levels.map_or(0, |v| v as u64 + 1));
    payload.push(cfg.external as u8);
    payload.push(cfg.levelwise as u8);
    write_section(&mut payload, external_bytes);
    let encoded = {
        let _s = crate::obs::span::enter(crate::obs::Hist::CompressHuffman);
        huffman_encode(&qs.symbols)
    };
    write_section(&mut payload, &encoded);
    write_section(&mut payload, &qs.escapes_to_bytes());
    // schedule trailer (PR 6): appended *after* the sections so readers
    // that predate it — including `decompress` below — never look at it.
    // Must be a function of the config, never of the engine that ran, so
    // staged/fused differential pairs stay byte-identical.
    payload.push(if cfg.adaptive { 0 } else { 1 });
    let compressed = {
        let _s = crate::obs::span::enter(crate::obs::Hist::CompressLossless);
        lossless_compress(&payload, cfg.zstd_level)?
    };

    let mut out = Vec::with_capacity(compressed.len() + 64);
    Header {
        method: Method::MgardPlus,
        dtype: T::DTYPE_TAG,
        shape: shape.to_vec(),
        tau_abs: tau,
    }
    .write(&mut out);
    write_u64(&mut out, payload.len() as u64);
    out.extend_from_slice(&compressed);
    Ok(out)
}

/// Read the [`Schedule`] trailer of an MGARD+ container.
///
/// Returns `Ok(None)` for containers written before the trailer existed
/// (their payload ends exactly at the third section); `info` reports those
/// as unknown. Rejects non-MGARD+ containers and malformed trailer bytes.
pub fn container_schedule(bytes: &[u8]) -> Result<Option<Schedule>> {
    let (header, mut r) = Header::read(bytes)?;
    if header.method != Method::MgardPlus {
        return Err(Error::invalid(format!(
            "schedule trailer: container method is {}, expected mgard+",
            header.method
        )));
    }
    let payload_len = r.usize()?;
    let payload = lossless_decompress(r.bytes(r.remaining())?, payload_len)?;
    let mut pr = ByteReader::new(&payload);
    pr.usize()?; // stop level
    pr.usize()?; // max_levels encoding
    pr.u8()?; // external compressor tag
    pr.u8()?; // levelwise flag
    pr.section()?; // external coarse bytes
    pr.section()?; // huffman symbols
    pr.section()?; // quantizer escapes
    if pr.remaining() == 0 {
        return Ok(None); // pre-trailer container
    }
    match pr.u8()? {
        0 => Ok(Some(Schedule::Adaptive)),
        1 => Ok(Some(Schedule::Static)),
        other => Err(Error::corrupt(format!("schedule trailer byte {other}"))),
    }
}

impl<T: Scalar> Compressor<T> for MgardPlus {
    fn name(&self) -> &'static str {
        "MGARD+"
    }

    fn compress(&self, data: &Tensor<T>, tol: Tolerance) -> Result<Vec<u8>> {
        self.compress_scratch(data, tol, &mut CodecScratch::new())
    }

    fn compress_scratch(
        &self,
        data: &Tensor<T>,
        tol: Tolerance,
        ws: &mut CodecScratch<T>,
    ) -> Result<Vec<u8>> {
        let tau = tol.absolute(data.value_range());
        if tau <= 0.0 {
            return Err(Error::invalid("tolerance must be positive"));
        }
        let hierarchy = Hierarchy::new(data.shape(), self.cfg.max_levels)?;
        let d = data.ndim();
        let ll = hierarchy.nlevels();
        let k = kappa(d);

        // --- adaptive multilevel decomposition (Alg. 1 lines 2–16) ---
        // The level-L check runs on the *original* data: if the external
        // compressor wins before any decomposition, we hand it the unpadded
        // input and skip the dummy-node overhead entirely.
        if self.cfg.adaptive {
            let tau0 = tau / self.cfg.c_linf; // remaining = 1 tier at l = L
            let est = {
                let _s = crate::obs::span::enter(crate::obs::Hist::CompressEstimate);
                estimate_predictors(
                    data.data(),
                    data.shape(),
                    tau0,
                    self.cfg.sample_stride.max(1),
                )
            };
            // The multilevel path pays for every *padded* node (dummy-node
            // handling of non-dyadic dims), the external path only for the
            // original ones; weight the per-sample estimates by the point
            // counts each predictor would actually code.
            let inflation = hierarchy.level_numel(ll) as f64 / data.len() as f64;
            if est.samples > 0 && est.lorenzo < est.interp * inflation {
                let external_bytes = self.cfg.external.compress(data, tau0)?;
                // stop == L is the direct-external sentinel: no padding, no
                // recomposition at decompress time
                return finish_container::<T>(
                    data.shape(),
                    tau,
                    &self.cfg,
                    ll,
                    &external_bytes,
                    &QuantStream::default(),
                );
            }
        }

        // --- fused single pass (decompose→quantize, §5-style fusion) ---
        // The tier schedule depends on the stop level, so the fused path
        // requires it static: adaptive termination off means stop == 0 and
        // every level's tolerance is known before the first step. Output
        // bytes are bit-identical to the staged path below (differential
        // suite in rust/tests/decompose_equivalence.rs).
        if self.cfg.flags.fused && !self.cfg.adaptive {
            let tiers = self.tiers(ll + 1, d, tau);
            let padded = hierarchy.pad(data)?;
            let coarse = {
                let _s = crate::obs::span::enter(crate::obs::Hist::CompressFused);
                fused::decompose_quantize(
                    &hierarchy,
                    self.cfg.flags,
                    padded,
                    &tiers,
                    &mut ws.decompose,
                    &mut ws.fused,
                )
            };
            let external_bytes = self.cfg.external.compress(&coarse, tiers[0])?;
            return finish_container::<T>(
                data.shape(),
                tau,
                &self.cfg,
                0,
                &external_bytes,
                &ws.fused.merged,
            );
        }

        // --- staged path (adaptive termination interleaved) ---
        // Per-level coefficient streams come from the scratch pool, so the
        // steady-state allocation count stays O(1) per call here too.
        let padded = hierarchy.pad(data)?;
        let mut cur = padded.into_vec();
        let mut shape = hierarchy.padded_shape().to_vec();
        while ws.streams.len() < ll {
            ws.streams.push(Vec::new());
        }
        let mut nsteps = 0usize;
        let mut stop = 0usize;
        for l in (1..=ll).rev() {
            if self.cfg.adaptive && l < ll {
                // tolerance the current level would get if decomposition
                // stopped here (Alg. 1 line 3)
                let remaining = ll + 1 - l;
                let tau0 = (1.0 - k) / (1.0 - k.powi(remaining as i32)) * tau / self.cfg.c_linf;
                let est = {
                    let _s = crate::obs::span::enter(crate::obs::Hist::CompressEstimate);
                    estimate_predictors(&cur, &shape, tau0, self.cfg.sample_stride.max(1))
                };
                if est.should_terminate() {
                    stop = l;
                    break;
                }
            }
            let sink = &mut ws.streams[nsteps];
            sink.clear();
            let _s = crate::obs::span::enter(crate::obs::Hist::CompressDecompose);
            shape = contiguous::step_decompose_into(
                &mut cur,
                &shape,
                self.cfg.flags,
                hierarchy.spacing(l),
                &mut ws.decompose,
                sink,
            );
            drop(_s);
            nsteps += 1;
        }
        let coarse = Tensor::from_vec(&shape, cur)?;

        // --- level-wise quantization + external coarse compression ---
        let tiers = self.tiers(ll + 1 - stop, d, tau);
        let external_bytes = self.cfg.external.compress(&coarse, tiers[0])?;
        ws.qs.symbols.clear();
        ws.qs.escapes.clear();
        // streams were collected finest-first; the container stores them
        // coarsest level first
        {
            let _s = crate::obs::span::enter(crate::obs::Hist::CompressQuantize);
            for (i, idx) in (0..nsteps).rev().enumerate() {
                quantize(&ws.streams[idx], tiers[i + 1], &mut ws.qs);
            }
        }
        finish_container::<T>(data.shape(), tau, &self.cfg, stop, &external_bytes, &ws.qs)
    }

    fn decompress(&self, bytes: &[u8]) -> Result<Tensor<T>> {
        let (header, mut r) = Header::read(bytes)?;
        header.expect::<T>(Method::MgardPlus)?;
        let payload_len = r.usize()?;
        let payload = {
            let _s = crate::obs::span::enter(crate::obs::Hist::DecompressLossless);
            lossless_decompress(r.bytes(r.remaining())?, payload_len)?
        };
        let mut pr = ByteReader::new(&payload);
        let stop = pr.usize()?;
        let max_levels_enc = pr.usize()?;
        let max_levels = if max_levels_enc == 0 {
            None
        } else {
            Some(max_levels_enc - 1)
        };
        let external = ExternalChoice::from_u8(pr.u8()?)?;
        let levelwise = pr.u8()? == 1;
        let external_bytes = pr.section()?;
        let symbols = {
            let _s = crate::obs::span::enter(crate::obs::Hist::DecompressHuffman);
            huffman_decode(pr.section()?)?
        };
        let escapes = QuantStream::escapes_from_bytes(pr.section()?)?;

        let hierarchy = Hierarchy::new(&header.shape, max_levels)?;
        let ll = hierarchy.nlevels();
        if stop > ll {
            return Err(Error::corrupt(format!("stop level {stop} > L = {ll}")));
        }
        if stop == ll {
            // direct-external sentinel: the external container holds the
            // original (unpadded) tensor
            let out: Tensor<T> = external.decompress(external_bytes)?;
            if out.shape() != header.shape.as_slice() {
                return Err(Error::corrupt("direct-external shape mismatch"));
            }
            return Ok(out);
        }
        let d = header.shape.len();
        let tiers = if levelwise {
            level_tolerances(ll + 1 - stop, d, header.tau_abs, self.cfg.c_linf)
        } else {
            vec![
                header.tau_abs / (self.cfg.c_linf * (ll + 1 - stop) as f64);
                ll + 1 - stop
            ]
        };

        let coarse: Tensor<T> = external.decompress(external_bytes)?;
        if coarse.shape() != hierarchy.level_shape(stop).as_slice() {
            return Err(Error::corrupt("coarse representation shape mismatch"));
        }
        let mut cursor = 0usize;
        let mut esc_cursor = 0usize;
        let mut coeffs = Vec::with_capacity(ll - stop);
        let dequant_span = crate::obs::span::enter(crate::obs::Hist::DecompressDequantize);
        for l in (stop + 1)..=ll {
            let n = hierarchy.num_coeff_nodes(l);
            if cursor + n > symbols.len() {
                return Err(Error::corrupt("coefficient stream too short"));
            }
            let mut vals: Vec<T> = Vec::with_capacity(n);
            dequantize(
                &symbols[cursor..cursor + n],
                &escapes,
                &mut esc_cursor,
                tiers[l - stop],
                &mut vals,
            )?;
            cursor += n;
            coeffs.push(vals);
        }
        drop(dequant_span);

        let dec = Decomposition {
            hierarchy: hierarchy.clone(),
            start_level: stop,
            coarse,
            coeffs,
        };
        let _s = crate::obs::span::enter(crate::obs::Hist::DecompressRecompose);
        Decomposer::new(hierarchy, OptFlags::all())?.recompose(&dec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{linf_error, psnr};

    #[test]
    fn error_bound_across_tolerances() {
        let t = crate::data::synth::smooth_test_field(&[20, 20, 20]);
        let m = MgardPlus::default();
        for tau in [1e-1, 1e-2, 1e-3, 1e-4] {
            let bytes = m.compress(&t, Tolerance::Abs(tau)).unwrap();
            let back: Tensor<f32> = m.decompress(&bytes).unwrap();
            let err = linf_error(t.data(), back.data());
            assert!(err <= tau, "τ={tau}: err {err}");
        }
    }

    #[test]
    fn ablation_variants_bounded() {
        let t = crate::data::synth::smooth_test_field(&[17, 17]);
        for cfg in [
            MgardPlusConfig::default(),
            MgardPlusConfig::lq_only(),
            MgardPlusConfig::ad_only(),
        ] {
            let m = MgardPlus::new(cfg);
            let bytes = m.compress(&t, Tolerance::Abs(1e-3)).unwrap();
            let back: Tensor<f32> = m.decompress(&bytes).unwrap();
            assert!(linf_error(t.data(), back.data()) <= 1e-3, "{cfg:?}");
        }
    }

    #[test]
    fn beats_uniform_quantization_on_smooth_data() {
        // The §4.1 claim: at equal (high) tolerance, level-wise quantization
        // compresses better than the uniform MGARD baseline at similar PSNR.
        let t = crate::data::synth::smooth_test_field(&[33, 33, 33]);
        let tau = Tolerance::Rel(1e-2);
        let plus = MgardPlus::new(MgardPlusConfig::lq_only());
        let base = super::super::Mgard::optimized_engine();
        let b_plus = plus.compress(&t, tau).unwrap();
        let b_base = Compressor::<f32>::compress(&base, &t, tau).unwrap();
        let r_plus: Tensor<f32> = plus.decompress(&b_plus).unwrap();
        let r_base: Tensor<f32> = base.decompress(&b_base).unwrap();
        let p_plus = psnr(t.data(), r_plus.data());
        let p_base = psnr(t.data(), r_base.data());
        // compare bytes-per-dB-ish: LQ should need fewer bytes without losing
        // much quality
        assert!(
            (b_plus.len() as f64) < (b_base.len() as f64) * 1.05,
            "LQ {} bytes vs uniform {} bytes (PSNR {p_plus:.1} vs {p_base:.1})",
            b_plus.len(),
            b_base.len()
        );
    }

    #[test]
    fn four_dimensional_data() {
        let t = crate::data::synth::smooth_test_field(&[6, 8, 8, 8]);
        let m = MgardPlus::default();
        let bytes = m.compress(&t, Tolerance::Abs(1e-2)).unwrap();
        let back: Tensor<f32> = m.decompress(&bytes).unwrap();
        assert!(linf_error(t.data(), back.data()) <= 1e-2);
    }

    #[test]
    fn schedule_trailer_reflects_config_not_engine() {
        let t = crate::data::synth::smooth_test_field(&[17, 17]);
        // adaptive on -> Adaptive, regardless of the fused flag (which is
        // inert under adaptive termination)
        let adaptive = MgardPlus::default()
            .compress(&t, Tolerance::Abs(1e-3))
            .unwrap();
        assert_eq!(
            container_schedule(&adaptive).unwrap(),
            Some(Schedule::Adaptive)
        );
        // adaptive off -> Static, identically for the staged and fused engines
        for flags in [OptFlags::all_staged(), OptFlags::all()] {
            let cfg = MgardPlusConfig {
                adaptive: false,
                flags,
                ..MgardPlusConfig::default()
            };
            let bytes = MgardPlus::new(cfg).compress(&t, Tolerance::Abs(1e-3)).unwrap();
            assert_eq!(
                container_schedule(&bytes).unwrap(),
                Some(Schedule::Static),
                "{flags:?}"
            );
        }
        // non-MGARD+ containers are rejected, not misread
        let sz = Sz::default().compress(&t, Tolerance::Abs(1e-3)).unwrap();
        assert!(container_schedule(&sz).is_err());
    }

    #[test]
    fn f64_round_trip() {
        let t = Tensor::<f64>::from_fn(&[15, 15], |ix| {
            ((ix[0] as f64) * 0.4).sin() * ((ix[1] as f64) * 0.3).cos()
        });
        let m = MgardPlus::default();
        let bytes = m.compress(&t, Tolerance::Abs(1e-6)).unwrap();
        let back: Tensor<f64> = m.decompress(&bytes).unwrap();
        assert!(linf_error(t.data(), back.data()) <= 1e-6);
    }
}
