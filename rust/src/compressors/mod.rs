//! Error-bounded lossy compressors: MGARD+ (§4/Alg. 1), plus faithful-shape
//! reimplementations of the paper's comparison points — MGARD [11], SZ [7],
//! ZFP [3] and the hybrid model [9].
//!
//! All compressors implement [`Compressor`]: compress a [`Tensor`] under an
//! L∞ [`Tolerance`] into a self-describing byte container, and decompress it
//! back. Every implementation guarantees `‖u − ũ‖_∞ ≤ τ` (tested in
//! `rust/tests/error_bounds.rs`).

mod format;
mod hybrid;
mod mgard;
mod mgard_plus;
mod scratch;
mod sz;
mod zfp;

pub use format::{peek_method, Header, Method, MAX_HEADER_NUMEL};
pub use hybrid::{Hybrid, HybridConfig};
pub use mgard::{Mgard, MgardConfig};
pub use mgard_plus::{container_schedule, ExternalChoice, MgardPlus, MgardPlusConfig, Schedule};
pub use scratch::CodecScratch;
pub use sz::{Sz, SzConfig};
pub use zfp::{Zfp, ZfpConfig};

pub(crate) use scratch::HybridScratch;

use crate::error::Result;
use crate::tensor::{Scalar, Tensor};

/// L∞ error tolerance specification.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Tolerance {
    /// Absolute bound on `max_i |u_i − ũ_i|`.
    Abs(f64),
    /// Bound relative to the value range: `τ_abs = rel · (max u − min u)`.
    Rel(f64),
}

impl Tolerance {
    /// Resolve to an absolute tolerance given the data's value range.
    pub fn absolute(&self, value_range: f64) -> f64 {
        match *self {
            Tolerance::Abs(t) => t,
            Tolerance::Rel(r) => {
                let range = if value_range > 0.0 { value_range } else { 1.0 };
                r * range
            }
        }
    }
}

/// A lossy error-bounded compressor over tensors of `T`.
pub trait Compressor<T: Scalar> {
    /// Short display name (used in benchmark tables).
    fn name(&self) -> &'static str;

    /// Compress `data` with the given L∞ tolerance.
    fn compress(&self, data: &Tensor<T>, tol: Tolerance) -> Result<Vec<u8>>;

    /// Compress `data`, reusing `scratch` for internal working memory.
    ///
    /// Semantics and output bytes are **identical** to
    /// [`Compressor::compress`]; implementations that override this (the
    /// MGARD+ and hybrid hot paths) only avoid re-allocating workspace, so
    /// a caller compressing many blocks — the chunk worker pool, the
    /// streaming pipeline — threads one [`CodecScratch`] per worker
    /// through every call and gets O(1) steady-state allocations per
    /// block. The default ignores the scratch and delegates to
    /// `compress`.
    fn compress_scratch(
        &self,
        data: &Tensor<T>,
        tol: Tolerance,
        scratch: &mut CodecScratch<T>,
    ) -> Result<Vec<u8>> {
        let _ = scratch;
        self.compress(data, tol)
    }

    /// Decompress a container produced by this compressor.
    fn decompress(&self, bytes: &[u8]) -> Result<Tensor<T>>;
}

/// Decompress any container produced by any compressor in this crate,
/// dispatching on the header's method tag (including chunked containers,
/// whose blocks dispatch individually on their own headers).
pub fn decompress_any<T: Scalar>(bytes: &[u8]) -> Result<Tensor<T>> {
    let method = format::peek_method(bytes)?;
    match method {
        Method::Mgard => Mgard::default().decompress(bytes),
        Method::MgardPlus => MgardPlus::default().decompress(bytes),
        Method::Sz => Sz::default().decompress(bytes),
        Method::Zfp => Zfp::default().decompress(bytes),
        Method::Hybrid => Hybrid::default().decompress(bytes),
        Method::Chunked => crate::chunk::decompress_any_chunked(bytes),
    }
}

/// Streaming counterpart of [`decompress_any`] for seekable byte streams:
/// chunked containers decode block-at-a-time through
/// [`crate::stream::StreamingDecompressor`] (the blob section never loads
/// as a whole), while single-tensor containers fall back to an in-memory
/// read — their payloads are monolithic by construction.
pub fn decompress_any_from<T: Scalar, R: std::io::Read + std::io::Seek>(
    mut src: R,
) -> Result<Tensor<T>> {
    use std::io::{Read, Seek, SeekFrom};
    // a 128-byte probe covers the worst-case header (8 dims × 10-byte
    // varints plus the fixed fields is 96 bytes)
    let mut probe = [0u8; 128];
    src.seek(SeekFrom::Start(0))?;
    let mut got = 0;
    while got < probe.len() {
        let n = src.read(&mut probe[got..])?;
        if n == 0 {
            break;
        }
        got += n;
    }
    let method = format::peek_method(&probe[..got])?;
    src.seek(SeekFrom::Start(0))?;
    if method == Method::Chunked {
        let mut d = crate::stream::StreamingDecompressor::open(src)?;
        d.decompress()
    } else {
        let mut bytes = Vec::new();
        src.read_to_end(&mut bytes)?;
        decompress_any(&bytes)
    }
}

/// All five compressors with their default configurations (the Fig. 8/10/11
/// comparison set).
pub fn all_compressors<T: Scalar>() -> Vec<Box<dyn Compressor<T>>> {
    vec![
        Box::new(Sz::default()),
        Box::new(Zfp::default()),
        Box::new(Hybrid::default()),
        Box::new(Mgard::default()),
        Box::new(MgardPlus::default()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tolerance_resolution() {
        assert_eq!(Tolerance::Abs(0.5).absolute(100.0), 0.5);
        assert_eq!(Tolerance::Rel(1e-3).absolute(100.0), 0.1);
        // degenerate constant field: fall back to unit range
        assert_eq!(Tolerance::Rel(1e-3).absolute(0.0), 1e-3);
    }

    #[test]
    fn compressor_set_is_complete() {
        let set = all_compressors::<f32>();
        assert_eq!(set.len(), 5);
        let names: Vec<_> = set.iter().map(|c| c.name()).collect();
        assert!(names.contains(&"SZ"));
        assert!(names.contains(&"ZFP"));
        assert!(names.contains(&"HybridModel"));
        assert!(names.contains(&"MGARD"));
        assert!(names.contains(&"MGARD+"));
    }
}
