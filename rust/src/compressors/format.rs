//! Self-describing container format shared by all compressors.
//!
//! Layout: magic `MGRP`, version, method tag, dtype tag, ndim, dims
//! (varints), absolute tolerance (f64), then a method-specific payload.

use crate::encode::varint::{write_f64, write_u64, ByteReader};
use crate::error::{Error, Result};
use crate::tensor::Scalar;

const MAGIC: &[u8; 4] = b"MGRP";
const VERSION: u8 = 1;

/// Largest element count a container header may declare (2^33 ≈ 8.6e9
/// points — generously above any field in the paper's datasets).
pub const MAX_HEADER_NUMEL: usize = 1 << 33;

/// Compression method tag stored in the container.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Original multilevel compressor (uniform quantization).
    Mgard = 1,
    /// This paper's compressor (Alg. 1).
    MgardPlus = 2,
    /// Prediction-based baseline.
    Sz = 3,
    /// Transform-based baseline.
    Zfp = 4,
    /// SZ framework with transform predictor.
    Hybrid = 5,
    /// Chunked container: independently compressed blocks of any of the
    /// above, plus a per-block index (see `crate::chunk`).
    Chunked = 6,
}

impl std::fmt::Display for Method {
    /// CLI-facing name, matching the `--method` spellings where one exists.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Method::Mgard => "mgard",
            Method::MgardPlus => "mgard+",
            Method::Sz => "sz",
            Method::Zfp => "zfp",
            Method::Hybrid => "hybrid",
            Method::Chunked => "chunked",
        };
        f.write_str(s)
    }
}

impl Method {
    pub(crate) fn from_u8(v: u8) -> Result<Method> {
        Ok(match v {
            1 => Method::Mgard,
            2 => Method::MgardPlus,
            3 => Method::Sz,
            4 => Method::Zfp,
            5 => Method::Hybrid,
            6 => Method::Chunked,
            other => return Err(Error::UnsupportedFormat(format!("method tag {other}"))),
        })
    }
}

/// Parsed container header.
#[derive(Clone, Debug, PartialEq)]
pub struct Header {
    /// Which compressor wrote the container.
    pub method: Method,
    /// Scalar type tag (`Scalar::DTYPE_TAG`).
    pub dtype: u8,
    /// Original tensor shape.
    pub shape: Vec<usize>,
    /// Absolute L∞ tolerance used at compression time.
    pub tau_abs: f64,
}

impl Header {
    /// Serialize the header to the front of `out`.
    pub fn write(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(MAGIC);
        out.push(VERSION);
        out.push(self.method as u8);
        out.push(self.dtype);
        write_u64(out, self.shape.len() as u64);
        for &d in &self.shape {
            write_u64(out, d as u64);
        }
        write_f64(out, self.tau_abs);
    }

    /// Parse a header, returning it and a reader positioned at the payload.
    pub fn read(bytes: &[u8]) -> Result<(Header, ByteReader<'_>)> {
        let mut r = ByteReader::new(bytes);
        if r.bytes(4)? != MAGIC {
            return Err(Error::UnsupportedFormat("bad magic".into()));
        }
        let version = r.u8()?;
        if version != VERSION {
            return Err(Error::UnsupportedFormat(format!(
                "container version {version}, expected {VERSION}"
            )));
        }
        let method = Method::from_u8(r.u8()?)?;
        let dtype = r.u8()?;
        let ndim = r.usize()?;
        if ndim == 0 || ndim > 8 {
            return Err(Error::corrupt(format!("implausible ndim {ndim}")));
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.usize()?);
        }
        // bound the declared element count so corrupted shape fields can
        // neither overflow stride/numel arithmetic downstream nor set up
        // absurd allocations before payload-length validation kicks in
        let numel = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .filter(|&n| n <= MAX_HEADER_NUMEL);
        if numel.is_none() {
            return Err(Error::corrupt(format!("implausible shape {shape:?}")));
        }
        let tau_abs = r.f64()?;
        Ok((
            Header {
                method,
                dtype,
                shape,
                tau_abs,
            },
            r,
        ))
    }

    /// Validate the header against the expected method and scalar type.
    pub fn expect<T: Scalar>(&self, method: Method) -> Result<()> {
        if self.method != method {
            return Err(Error::UnsupportedFormat(format!(
                "container written by {:?}, decompressor is {:?}",
                self.method, method
            )));
        }
        if self.dtype != T::DTYPE_TAG {
            return Err(Error::UnsupportedFormat(format!(
                "container dtype tag {} does not match requested scalar ({})",
                self.dtype,
                T::DTYPE_TAG
            )));
        }
        Ok(())
    }
}

/// Peek at the method tag without fully parsing.
pub fn peek_method(bytes: &[u8]) -> Result<Method> {
    let (h, _) = Header::read(bytes)?;
    Ok(h.method)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_round_trip() {
        let h = Header {
            method: Method::MgardPlus,
            dtype: 1,
            shape: vec![100, 500, 500],
            tau_abs: 1.5e-3,
        };
        let mut buf = Vec::new();
        h.write(&mut buf);
        buf.extend_from_slice(b"PAYLOAD");
        let (back, mut r) = Header::read(&buf).unwrap();
        assert_eq!(h, back);
        assert_eq!(r.bytes(7).unwrap(), b"PAYLOAD");
    }

    #[test]
    fn bad_magic_rejected() {
        assert!(Header::read(b"NOPE....").is_err());
        assert!(Header::read(b"MG").is_err());
    }

    #[test]
    fn method_dispatch_tags() {
        for m in [
            Method::Mgard,
            Method::MgardPlus,
            Method::Sz,
            Method::Zfp,
            Method::Hybrid,
            Method::Chunked,
        ] {
            assert_eq!(Method::from_u8(m as u8).unwrap(), m);
        }
        assert!(Method::from_u8(99).is_err());
    }

    #[test]
    fn method_display_names() {
        assert_eq!(Method::MgardPlus.to_string(), "mgard+");
        assert_eq!(Method::Chunked.to_string(), "chunked");
    }

    #[test]
    fn expect_checks_method_and_dtype() {
        let h = Header {
            method: Method::Sz,
            dtype: 1,
            shape: vec![4],
            tau_abs: 0.1,
        };
        assert!(h.expect::<f32>(Method::Sz).is_ok());
        assert!(h.expect::<f64>(Method::Sz).is_err());
        assert!(h.expect::<f32>(Method::Zfp).is_err());
    }
}
