//! SZ-style prediction-based error-bounded compressor (the paper's external
//! compressor and primary baseline [7]).
//!
//! Faithful-shape reimplementation of SZ 2.x: data is processed in 6ᵈ
//! blocks; each block adaptively selects between the Lorenzo predictor
//! (running on *reconstructed* data, penalty-adjusted selection as in [7])
//! and a block-local linear-regression predictor (coefficients fitted to the
//! original data, quantized, and shipped); prediction residuals go through
//! linear-scaling quantization with an unpredictable-literal escape, then
//! canonical Huffman + the in-tree LZ codec.

use super::format::{Header, Method};
use super::{Compressor, Tolerance};
use crate::encode::varint::{write_i64, write_section, write_u64, ByteReader};
use crate::encode::{huffman_decode, huffman_encode, lossless_compress, lossless_decompress};
use crate::error::{Error, Result};
use crate::tensor::{strides_for, Scalar, Tensor};

/// SZ configuration.
#[derive(Clone, Copy, Debug)]
pub struct SzConfig {
    /// Block edge length (SZ uses 6 for 3-D).
    pub block_edge: usize,
    /// Quantization radius: codes live in `[-radius+1, radius-1]`.
    pub radius: i64,
    /// Lossless-stage effort level (kept as `zstd_level` for config compatibility).
    pub zstd_level: i32,
}

impl Default for SzConfig {
    fn default() -> Self {
        SzConfig {
            block_edge: 6,
            radius: 32768,
            zstd_level: 3,
        }
    }
}

/// The SZ compressor.
#[derive(Clone, Debug, Default)]
pub struct Sz {
    cfg: SzConfig,
}

impl Sz {
    /// Build with an explicit configuration.
    pub fn new(cfg: SzConfig) -> Self {
        Sz { cfg }
    }
}

/// Lorenzo prediction from reconstructed data; out-of-domain neighbors
/// contribute zero (consistent across compression and decompression).
#[inline]
fn lorenzo_pred<T: Scalar>(
    recon: &[T],
    idx: &[usize],
    strides: &[usize],
) -> f64 {
    let d = idx.len();
    let mut acc = 0.0f64;
    'mask: for mask in 1..(1usize << d) {
        let mut off = 0usize;
        for k in 0..d {
            if mask & (1 << k) != 0 {
                if idx[k] == 0 {
                    continue 'mask; // neighbor outside: contributes 0
                }
                off += (idx[k] - 1) * strides[k];
            } else {
                off += idx[k] * strides[k];
            }
        }
        let sign = if mask.count_ones() % 2 == 1 { 1.0 } else { -1.0 };
        acc += sign * recon[off].to_f64();
    }
    acc
}

/// Per-block linear regression `v ≈ b0 + Σ_d bd·x_d` (local coords), fitted
/// separably (valid for rectangular blocks), returning `[b0, b1, ..]`.
fn fit_regression<T: Scalar>(
    data: &[T],
    strides: &[usize],
    origin: &[usize],
    bsize: &[usize],
) -> Vec<f64> {
    let d = bsize.len();
    let n: usize = bsize.iter().product();
    let mut mean = 0.0f64;
    let mut cov = vec![0.0f64; d];
    let centers: Vec<f64> = bsize.iter().map(|&b| (b as f64 - 1.0) / 2.0).collect();
    let vars: Vec<f64> = bsize
        .iter()
        .map(|&b| {
            // variance of 0..b-1 around its center
            let c = (b as f64 - 1.0) / 2.0;
            (0..b).map(|i| (i as f64 - c).powi(2)).sum::<f64>() / b as f64
        })
        .collect();
    let mut idx = vec![0usize; d];
    for _ in 0..n {
        let mut off = 0;
        for k in 0..d {
            off += (origin[k] + idx[k]) * strides[k];
        }
        let v = data[off].to_f64();
        mean += v;
        for k in 0..d {
            cov[k] += (idx[k] as f64 - centers[k]) * v;
        }
        for k in (0..d).rev() {
            idx[k] += 1;
            if idx[k] < bsize[k] {
                break;
            }
            idx[k] = 0;
        }
    }
    mean /= n as f64;
    let mut out = vec![0.0; d + 1];
    for k in 0..d {
        out[k + 1] = if vars[k] > 0.0 {
            cov[k] / (n as f64 * vars[k])
        } else {
            0.0
        };
    }
    out[0] = mean - (0..d).map(|k| out[k + 1] * centers[k]).sum::<f64>();
    out
}

/// Regression-coefficient quantization tolerance for a given data tolerance.
fn reg_tau(tau: f64, d: usize, edge: usize) -> f64 {
    tau / (2.0 * (d as f64 + 1.0) * edge as f64)
}

impl<T: Scalar> Compressor<T> for Sz {
    fn name(&self) -> &'static str {
        "SZ"
    }

    fn compress(&self, data: &Tensor<T>, tol: Tolerance) -> Result<Vec<u8>> {
        let tau = tol.absolute(data.value_range());
        if tau <= 0.0 {
            return Err(Error::invalid("tolerance must be positive"));
        }
        let shape = data.shape().to_vec();
        let d = shape.len();
        if d > 4 {
            return Err(Error::invalid("SZ supports up to 4 dimensions"));
        }
        let strides = strides_for(&shape);
        let edge = self.cfg.block_edge;
        let radius = self.cfg.radius;
        let src = data.data();
        let mut recon = vec![T::ZERO; src.len()];

        let nblocks: Vec<usize> = shape.iter().map(|&n| n.div_ceil(edge)).collect();
        let total_blocks: usize = nblocks.iter().product();
        let lorenzo_penalty = crate::adaptive::lorenzo_penalty_factor(d) * tau;
        let rt = reg_tau(tau, d, edge);

        let mut symbols: Vec<u32> = Vec::with_capacity(src.len());
        let mut literals: Vec<u8> = Vec::new();
        let mut flags: Vec<u8> = Vec::with_capacity(total_blocks); // 0=lorenzo 1=regression
        let mut reg_codes: Vec<u8> = Vec::new();

        let mut bidx = vec![0usize; d];
        let mut pt = vec![0usize; d];
        for _ in 0..total_blocks {
            let origin: Vec<usize> = (0..d).map(|k| bidx[k] * edge).collect();
            let bsize: Vec<usize> = (0..d)
                .map(|k| edge.min(shape[k] - origin[k]))
                .collect();
            let bn: usize = bsize.iter().product();

            // --- predictor selection on original data ---
            let coeffs = fit_regression(src, &strides, &origin, &bsize);
            // quantize coefficients now: selection must use what the decoder
            // will see
            let qcoeffs: Vec<f64> = coeffs
                .iter()
                .map(|&c| (c / (2.0 * rt)).round() * 2.0 * rt)
                .collect();
            let mut err_lor = 0.0f64;
            let mut err_reg = 0.0f64;
            {
                let mut i = vec![0usize; d];
                for _ in 0..bn {
                    let mut off = 0;
                    for k in 0..d {
                        pt[k] = origin[k] + i[k];
                        off += pt[k] * strides[k];
                    }
                    let v = src[off].to_f64();
                    // Lorenzo estimate uses original data + penalty (Eq. 3)
                    let lp = lorenzo_pred(src, &pt, &strides);
                    err_lor += (lp - v).abs() + lorenzo_penalty;
                    let rp = qcoeffs[0]
                        + (0..d).map(|k| qcoeffs[k + 1] * i[k] as f64).sum::<f64>();
                    err_reg += (rp - v).abs();
                    for k in (0..d).rev() {
                        i[k] += 1;
                        if i[k] < bsize[k] {
                            break;
                        }
                        i[k] = 0;
                    }
                }
            }
            let use_reg = err_reg < err_lor;
            flags.push(use_reg as u8);
            if use_reg {
                for &c in &coeffs {
                    write_i64(&mut reg_codes, (c / (2.0 * rt)).round() as i64);
                }
            }

            // --- encode block points ---
            let mut i = vec![0usize; d];
            for _ in 0..bn {
                let mut off = 0;
                for k in 0..d {
                    pt[k] = origin[k] + i[k];
                    off += pt[k] * strides[k];
                }
                let v = src[off].to_f64();
                let pred = if use_reg {
                    qcoeffs[0] + (0..d).map(|k| qcoeffs[k + 1] * i[k] as f64).sum::<f64>()
                } else {
                    lorenzo_pred(&recon, &pt, &strides)
                };
                let code = ((v - pred) / (2.0 * tau)).round();
                let ok = code.is_finite() && code.abs() < (radius - 1) as f64;
                if ok {
                    let rec = pred + code * 2.0 * tau;
                    // SZ's safety check: the T-precision roundtrip must honour τ
                    let rec_t = T::from_f64(rec);
                    if (rec_t.to_f64() - v).abs() <= tau {
                        symbols.push((code as i64 + radius) as u32);
                        recon[off] = rec_t;
                    } else {
                        symbols.push(0);
                        src[off].write_le(&mut literals);
                        recon[off] = src[off];
                    }
                } else {
                    symbols.push(0);
                    src[off].write_le(&mut literals);
                    recon[off] = src[off];
                }
                for k in (0..d).rev() {
                    i[k] += 1;
                    if i[k] < bsize[k] {
                        break;
                    }
                    i[k] = 0;
                }
            }

            for k in (0..d).rev() {
                bidx[k] += 1;
                if bidx[k] < nblocks[k] {
                    break;
                }
                bidx[k] = 0;
            }
        }

        // --- assemble container ---
        let mut payload = Vec::new();
        write_section(&mut payload, &flags);
        write_section(&mut payload, &reg_codes);
        write_section(&mut payload, &huffman_encode(&symbols));
        write_section(&mut payload, &literals);
        let compressed = lossless_compress(&payload, self.cfg.zstd_level)?;

        let mut out = Vec::with_capacity(compressed.len() + 64);
        Header {
            method: Method::Sz,
            dtype: T::DTYPE_TAG,
            shape,
            tau_abs: tau,
        }
        .write(&mut out);
        write_u64(&mut out, payload.len() as u64);
        out.extend_from_slice(&compressed);
        Ok(out)
    }

    fn decompress(&self, bytes: &[u8]) -> Result<Tensor<T>> {
        let (header, mut r) = Header::read(bytes)?;
        header.expect::<T>(Method::Sz)?;
        let tau = header.tau_abs;
        let shape = header.shape.clone();
        let d = shape.len();
        let strides = strides_for(&shape);
        let n: usize = shape.iter().product();
        let payload_len = r.usize()?;
        let payload = lossless_decompress(r.bytes(r.remaining())?, payload_len)?;
        let mut pr = ByteReader::new(&payload);
        let flags = pr.section()?.to_vec();
        let reg_codes_raw = pr.section()?.to_vec();
        let symbols = huffman_decode(pr.section()?)?;
        let literals = pr.section()?.to_vec();
        if symbols.len() != n {
            return Err(Error::corrupt(format!(
                "symbol stream has {} entries for {} points",
                symbols.len(),
                n
            )));
        }

        let edge = self.cfg.block_edge;
        let radius = self.cfg.radius;
        let rt = reg_tau(tau, d, edge);
        let nblocks: Vec<usize> = shape.iter().map(|&s| s.div_ceil(edge)).collect();
        let total_blocks: usize = nblocks.iter().product();
        if flags.len() != total_blocks {
            return Err(Error::corrupt("block flag stream size mismatch"));
        }

        let mut recon = vec![T::ZERO; n];
        let mut reg_reader = ByteReader::new(&reg_codes_raw);
        let mut lit_pos = 0usize;
        let mut sym_pos = 0usize;
        let mut bidx = vec![0usize; d];
        let mut pt = vec![0usize; d];
        for b in 0..total_blocks {
            let origin: Vec<usize> = (0..d).map(|k| bidx[k] * edge).collect();
            let bsize: Vec<usize> = (0..d)
                .map(|k| edge.min(shape[k] - origin[k]))
                .collect();
            let bn: usize = bsize.iter().product();
            let use_reg = flags[b] == 1;
            let mut qcoeffs = vec![0.0f64; d + 1];
            if use_reg {
                for qc in qcoeffs.iter_mut() {
                    *qc = reg_reader.i64()? as f64 * 2.0 * rt;
                }
            }
            let mut i = vec![0usize; d];
            for _ in 0..bn {
                let mut off = 0;
                for k in 0..d {
                    pt[k] = origin[k] + i[k];
                    off += pt[k] * strides[k];
                }
                let s = symbols[sym_pos];
                sym_pos += 1;
                if s == 0 {
                    if lit_pos + T::BYTES > literals.len() {
                        return Err(Error::corrupt("literal stream exhausted"));
                    }
                    recon[off] = T::read_le(&literals[lit_pos..]);
                    lit_pos += T::BYTES;
                } else {
                    let code = s as i64 - radius;
                    let pred = if use_reg {
                        qcoeffs[0]
                            + (0..d).map(|k| qcoeffs[k + 1] * i[k] as f64).sum::<f64>()
                    } else {
                        lorenzo_pred(&recon, &pt, &strides)
                    };
                    recon[off] = T::from_f64(pred + code as f64 * 2.0 * tau);
                }
                for k in (0..d).rev() {
                    i[k] += 1;
                    if i[k] < bsize[k] {
                        break;
                    }
                    i[k] = 0;
                }
            }
            for k in (0..d).rev() {
                bidx[k] += 1;
                if bidx[k] < nblocks[k] {
                    break;
                }
                bidx[k] = 0;
            }
        }
        Tensor::from_vec(&shape, recon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::metrics::linf_error;

    fn check_bound<T: Scalar>(data: &Tensor<T>, tau_abs: f64) -> (f64, usize) {
        let sz = Sz::default();
        let bytes = sz.compress(data, Tolerance::Abs(tau_abs)).unwrap();
        let back: Tensor<T> = sz.decompress(&bytes).unwrap();
        assert_eq!(back.shape(), data.shape());
        let err = linf_error(data.data(), back.data());
        assert!(
            err <= tau_abs * (1.0 + 1e-9),
            "L∞ {err} exceeds τ {tau_abs}"
        );
        (err, bytes.len())
    }

    #[test]
    fn smooth_3d_bound_and_ratio() {
        let t = Tensor::<f32>::from_fn(&[20, 20, 20], |ix| {
            ((ix[0] as f32) * 0.3).sin() + ((ix[1] as f32) * 0.2).cos() * (ix[2] as f32 * 0.1)
        });
        let (_, csize) = check_bound(&t, 1e-3);
        assert!(
            csize < t.nbytes() / 4,
            "SZ should compress smooth data ≥ 4x: {} vs {}",
            csize,
            t.nbytes()
        );
    }

    #[test]
    fn random_data_still_bounded() {
        let mut rng = Rng::new(2);
        let t = Tensor::<f32>::from_fn(&[13, 17], |_| rng.uniform_in(-1.0, 1.0) as f32);
        check_bound(&t, 0.05);
    }

    #[test]
    fn f64_support() {
        let t = Tensor::<f64>::from_fn(&[9, 9, 9], |ix| {
            (ix[0] + ix[1] * ix[2]) as f64 * 0.01
        });
        check_bound(&t, 1e-6);
    }

    #[test]
    fn dims_1_through_4() {
        let mut rng = Rng::new(5);
        for shape in [vec![50usize], vec![12, 15], vec![7, 8, 9], vec![5, 6, 4, 7]] {
            let t = Tensor::<f32>::from_fn(&shape, |ix| {
                ix.iter().sum::<usize>() as f32 * 0.1 + rng.uniform_in(-0.01, 0.01) as f32
            });
            check_bound(&t, 1e-3);
        }
    }

    #[test]
    fn linear_data_prefers_regression() {
        // purely linear block data: regression should predict near-exactly,
        // and the flags should mark (at least some) regression blocks
        let t = Tensor::<f32>::from_fn(&[12, 12, 12], |ix| {
            1.0 + 0.5 * ix[0] as f32 - 0.3 * ix[1] as f32 + 0.1 * ix[2] as f32
        });
        let sz = Sz::default();
        let bytes = sz.compress(&t, Tolerance::Abs(1e-4)).unwrap();
        let back: Tensor<f32> = sz.decompress(&bytes).unwrap();
        assert!(linf_error(t.data(), back.data()) <= 1e-4 * (1.0 + 1e-9));
        // linear data compresses extremely well
        assert!(bytes.len() < t.nbytes() / 10);
    }

    #[test]
    fn tolerance_zero_rejected() {
        let t = Tensor::<f32>::zeros(&[8, 8]);
        assert!(Sz::default().compress(&t, Tolerance::Abs(0.0)).is_err());
    }

    #[test]
    fn corrupt_container_rejected() {
        let t = Tensor::<f32>::from_fn(&[8, 8], |ix| ix[0] as f32);
        let mut bytes = Sz::default().compress(&t, Tolerance::Abs(0.01)).unwrap();
        bytes.truncate(bytes.len() / 2);
        assert!(<Sz as Compressor<f32>>::decompress(&Sz::default(), &bytes).is_err());
    }

    #[test]
    fn rel_tolerance_resolves_to_range() {
        let t = Tensor::<f32>::from_fn(&[30, 30], |ix| (ix[0] * 30 + ix[1]) as f32); // range 899
        let sz = Sz::default();
        let bytes = sz.compress(&t, Tolerance::Rel(1e-3)).unwrap();
        let back: Tensor<f32> = sz.decompress(&bytes).unwrap();
        let err = linf_error(t.data(), back.data());
        assert!(err <= 0.899 * (1.0 + 1e-9), "err {err}");
    }
}
