//! Streaming decompression of chunked containers.
//!
//! [`StreamingDecompressor`] parses only the container *prefix* (header +
//! per-block index) from any seekable byte stream, then decodes blocks on
//! demand: the blob section is never resident in memory. That enables
//! decompressing fields larger than RAM straight to a raw-file sink, and
//! random access to sub-domains via [`StreamingDecompressor::decompress_region`],
//! which touches only the blocks intersecting the requested box.

use crate::chunk::container::{self, ChunkIndex};
use crate::chunk::partition::intersect;
use crate::chunk::pool::{effective_threads, parallel_map};
use crate::compressors::{decompress_any, peek_method, Header, Method};
use crate::data::io;
use crate::error::{Error, Result};
use crate::tensor::{numel, Scalar, Tensor};
use std::io::{Read, Seek, SeekFrom, Write};

/// Upper bound on the container prefix (header + index) the reader will
/// buffer while parsing: ~16 MiB covers several hundred thousand block
/// entries, far beyond any partition the compressor emits.
const MAX_INDEX_PREFIX: u64 = 1 << 24;

impl StreamingDecompressor<crate::storage::StorageObject> {
    /// Open a container stored as object `key` of `storage`: every blob
    /// access becomes a ranged GET, so streaming decompression runs
    /// unchanged over any [`crate::storage::Storage`] backend (local
    /// directory, memory, or a simulated remote store).
    pub fn open_storage(
        storage: std::sync::Arc<dyn crate::storage::Storage>,
        key: &str,
    ) -> Result<Self> {
        Self::open(crate::storage::StorageObject::open(storage, key)?)
    }
}

/// Decodes a chunked container block-at-a-time from a seekable stream.
pub struct StreamingDecompressor<R: Read + Seek> {
    src: R,
    header: Header,
    index: ChunkIndex,
    /// Absolute byte offset of the blob section inside the stream.
    blob_start: u64,
    /// Declared blob-section length in bytes.
    blob_len: usize,
    /// Worker threads for batched block decoding (0 = available
    /// parallelism). Blob *reads* stay serial on the single stream handle;
    /// only the CPU-side decode fans out.
    threads: usize,
}

impl<R: Read + Seek> StreamingDecompressor<R> {
    /// Parse the prefix of a chunked container and validate that the
    /// stream physically holds the declared blob section, so a container
    /// truncated mid-stream errors here instead of at first block access.
    pub fn open(mut src: R) -> Result<Self> {
        let stream_len = src.seek(SeekFrom::End(0))?;
        src.seek(SeekFrom::Start(0))?;
        let mut buf: Vec<u8> = Vec::new();
        let cap = stream_len.min(MAX_INDEX_PREFIX);
        let (header, index, blob_start, blob_len) = loop {
            match container::read_index(&buf) {
                Ok(parsed) => break parsed,
                Err(e) => {
                    // only a CorruptStream can mean "prefix not fully
                    // buffered yet"; bad magic / wrong method / version
                    // mismatches (UnsupportedFormat) and index
                    // inconsistencies (BlobOutOfRange) are definitive, so
                    // fail fast instead of reading up to the prefix cap
                    let retryable = matches!(e, Error::CorruptStream(_));
                    if !retryable || buf.len() as u64 >= cap {
                        return Err(e);
                    }
                    // grow geometrically so huge indexes need few passes
                    let want = (buf.len().max(4096) as u64).min(cap - buf.len() as u64);
                    let old = buf.len();
                    buf.resize(old + want as usize, 0);
                    src.read_exact(&mut buf[old..])?;
                }
            }
        };
        let declared_end = (blob_start as u64)
            .checked_add(blob_len as u64)
            .ok_or_else(|| Error::corrupt("blob section length overflow"))?;
        if declared_end > stream_len {
            return Err(Error::corrupt(format!(
                "container truncated mid-stream: blob section needs {declared_end} bytes, \
                 stream holds {stream_len}"
            )));
        }
        // the partition writers always cover the field exactly; reject a
        // point-count mismatch up front so a missing or duplicated block
        // fails at open instead of surfacing as zero-filled output. (Like
        // the in-core assemble() check this is a point-count test: a
        // crafted index pairing an overlap with a compensating gap can
        // still pass — each point is only guaranteed to be covered *on
        // average*, not exactly once.)
        let covered: usize = index.entries.iter().map(|e| numel(&e.shape)).sum();
        if covered != numel(&header.shape) {
            return Err(Error::corrupt(format!(
                "block index covers {covered} points, field has {}",
                numel(&header.shape)
            )));
        }
        Ok(StreamingDecompressor {
            src,
            header,
            index,
            blob_start: blob_start as u64,
            blob_len,
            threads: 0,
        })
    }

    /// Set the decode worker count (0 = available parallelism, the
    /// default). Returns `self` for chaining after [`Self::open`].
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// The container header (field shape, dtype tag, absolute tolerance).
    pub fn header(&self) -> &Header {
        &self.header
    }

    /// The per-block index.
    pub fn index(&self) -> &ChunkIndex {
        &self.index
    }

    /// Number of blocks in the container.
    pub fn nblocks(&self) -> usize {
        self.index.entries.len()
    }

    /// Declared size of the blob section in bytes.
    pub fn blob_len(&self) -> usize {
        self.blob_len
    }

    /// Read block `i`'s blob bytes (already range-validated at open).
    fn read_blob(&mut self, i: usize) -> Result<Vec<u8>> {
        let e = self
            .index
            .entries
            .get(i)
            .ok_or_else(|| Error::invalid(format!("block {i} out of {}", self.nblocks())))?;
        self.src
            .seek(SeekFrom::Start(self.blob_start + e.offset as u64))?;
        let mut blob = vec![0u8; e.len];
        self.src.read_exact(&mut blob)?;
        Ok(blob)
    }

    /// Read blobs `lo..hi` serially, then decode them on the worker pool.
    /// The batch bounds resident memory to `hi - lo` blobs plus their
    /// decoded tensors while restoring the chunked format's decode
    /// parallelism on the streaming path.
    fn decode_batch<T: Scalar>(&mut self, lo: usize, hi: usize) -> Result<Vec<Tensor<T>>> {
        let mut blobs = Vec::with_capacity(hi - lo);
        for i in lo..hi {
            blobs.push(self.read_blob(i)?);
        }
        let inner = self.index.inner;
        let entries = &self.index.entries[lo..hi];
        let results = parallel_map(blobs.len(), self.threads, |k| {
            let method = peek_method(&blobs[k])?;
            if method != inner {
                return Err(Error::corrupt(format!(
                    "block {} is a {method:?} blob, index says {inner:?}",
                    lo + k
                )));
            }
            let block: Tensor<T> = decompress_any(&blobs[k])?;
            if block.shape() != entries[k].shape.as_slice() {
                return Err(Error::corrupt(format!(
                    "block {} decoded to {:?}, index says {:?}",
                    lo + k,
                    block.shape(),
                    entries[k].shape
                )));
            }
            Ok(block)
        });
        let mut out = Vec::with_capacity(results.len());
        for r in results {
            out.push(r?);
        }
        Ok(out)
    }

    /// Decode block `i` on demand.
    pub fn decompress_block<T: Scalar>(&mut self, i: usize) -> Result<Tensor<T>> {
        self.header.expect::<T>(Method::Chunked)?;
        let blob = self.read_blob(i)?;
        let method = peek_method(&blob)?;
        if method != self.index.inner {
            return Err(Error::corrupt(format!(
                "block {i} is a {method:?} blob, index says {:?}",
                self.index.inner
            )));
        }
        let block: Tensor<T> = decompress_any(&blob)?;
        let e = &self.index.entries[i];
        if block.shape() != e.shape.as_slice() {
            return Err(Error::corrupt(format!(
                "block {i} decoded to {:?}, index says {:?}",
                block.shape(),
                e.shape
            )));
        }
        Ok(block)
    }

    /// Decompress only the sub-domain `[start, start + shape)`: blocks that
    /// do not intersect the region are never read or decoded. The returned
    /// tensor has shape `shape` and satisfies the container's global L∞
    /// tolerance pointwise (every point is produced by exactly one block).
    pub fn decompress_region<T: Scalar>(
        &mut self,
        start: &[usize],
        shape: &[usize],
    ) -> Result<Tensor<T>> {
        self.header.expect::<T>(Method::Chunked)?;
        let field = self.header.shape.clone();
        if start.len() != field.len() || shape.len() != field.len() {
            return Err(Error::shape("region rank mismatch"));
        }
        for d in 0..field.len() {
            let inside = shape[d] > 0
                && matches!(start[d].checked_add(shape[d]), Some(end) if end <= field[d]);
            if !inside {
                return Err(Error::shape(format!(
                    "region [{start:?} + {shape:?}) outside field {field:?}"
                )));
            }
        }
        let mut out = Tensor::<T>::zeros(shape);
        let hits: Vec<(usize, Vec<usize>, Vec<usize>)> = self
            .index
            .entries
            .iter()
            .enumerate()
            .filter_map(|(i, e)| {
                intersect(start, shape, &e.start, &e.shape)
                    .map(|(is, ish)| (i, is, ish))
            })
            .collect();
        for (i, isect_start, isect_shape) in hits {
            let block: Tensor<T> = self.decompress_block(i)?;
            let e = &self.index.entries[i];
            let rel_block: Vec<usize> = isect_start
                .iter()
                .zip(&e.start)
                .map(|(&a, &b)| a - b)
                .collect();
            let rel_out: Vec<usize> = isect_start
                .iter()
                .zip(start)
                .map(|(&a, &b)| a - b)
                .collect();
            let piece = block.block(&rel_block, &isect_shape)?;
            out.set_block(&rel_out, &piece)?;
        }
        Ok(out)
    }

    /// Decompress the whole field into memory. Blocks are decoded in
    /// bounded parallel batches, so peak memory is the output plus one
    /// batch. Point-count coverage of the field by the index was already
    /// validated at [`Self::open`].
    pub fn decompress<T: Scalar>(&mut self) -> Result<Tensor<T>> {
        self.header.expect::<T>(Method::Chunked)?;
        let field = self.header.shape.clone();
        let mut out = Tensor::<T>::zeros(&field);
        let n = self.nblocks();
        let batch = 2 * effective_threads(self.threads, n);
        let mut lo = 0;
        while lo < n {
            let hi = (lo + batch).min(n);
            let blocks = self.decode_batch::<T>(lo, hi)?;
            for (k, block) in blocks.into_iter().enumerate() {
                let start = self.index.entries[lo + k].start.clone();
                out.set_block(&start, &block)?;
            }
            lo = hi;
        }
        Ok(out)
    }

    /// Decompress the whole field straight into a seekable raw-file sink
    /// (headerless little-endian, the layout
    /// [`crate::data::io::read_raw`] reads): the out-of-core mirror of the
    /// streaming compressor. Blocks decode in bounded parallel batches and
    /// scatter to the sink as each batch completes — neither the field nor
    /// the blob section is ever fully resident.
    pub fn decompress_to_raw<T: Scalar, W: Write + Seek>(&mut self, sink: &mut W) -> Result<u64> {
        self.header.expect::<T>(Method::Chunked)?;
        let field = self.header.shape.clone();
        let n = self.nblocks();
        let batch = 2 * effective_threads(self.threads, n);
        let mut lo = 0;
        while lo < n {
            let hi = (lo + batch).min(n);
            let blocks = self.decode_batch::<T>(lo, hi)?;
            for (k, block) in blocks.into_iter().enumerate() {
                let start = self.index.entries[lo + k].start.clone();
                io::write_raw_block(sink, &field, &start, &block)?;
            }
            lo = hi;
        }
        sink.flush()?;
        Ok((numel(&field) * T::BYTES) as u64)
    }
}
