//! Incremental chunked-container writer.
//!
//! The chunked container places the per-block index (whose offsets and
//! lengths are varint-encoded, hence variable-width) *before* the blob
//! section, so a byte-identical container cannot be emitted strictly
//! front-to-back while blocks are still being compressed. [`ContainerWriter`]
//! therefore spools blobs as they arrive — to a temporary file for the
//! out-of-core path, or to memory for small jobs — accumulates the
//! lightweight index, and at [`ContainerWriter::finalize`] writes the fully
//! patched prefix (header + index + section length) to the sink followed by
//! a bounded-buffer copy of the spool. Peak memory is the index plus one
//! copy buffer, never the blob section; the output is byte-identical to
//! [`crate::chunk::container::write_container`] fed the same blocks in the
//! same order.

use crate::chunk::container::{BlockEntry, ChunkIndex, TilingPolicy};
use crate::compressors::{peek_method, Method};
use crate::error::{Error, Result};
use crate::tensor::Scalar;
use std::fs;
use std::io::{self, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Distinguishes concurrently created spool files within one process.
static SPOOL_SEQ: AtomicU64 = AtomicU64::new(0);

/// Where pushed blobs wait for [`ContainerWriter::finalize`].
enum Spool {
    /// Blobs buffered in memory (fine when the compressed size is small).
    Mem(Vec<u8>),
    /// Blobs spooled to a temporary file (the out-of-core path). The file
    /// is deleted on finalize or drop.
    File { file: fs::File, path: PathBuf },
}

impl Spool {
    fn write_all(&mut self, blob: &[u8]) -> Result<()> {
        match self {
            Spool::Mem(v) => {
                v.extend_from_slice(blob);
                Ok(())
            }
            Spool::File { file, .. } => {
                file.write_all(blob)?;
                Ok(())
            }
        }
    }
}

impl Drop for Spool {
    fn drop(&mut self) {
        if let Spool::File { path, .. } = self {
            let _ = fs::remove_file(path);
        }
    }
}

/// Streams per-block blobs to any [`io::Write`] sink, back-patching the
/// chunk index when the stream is finalized.
///
/// Blocks must be pushed in tile-list order — row-major for fixed tilings
/// (the order [`crate::chunk::partition::partition`] enumerates),
/// depth-first for adaptive ones
/// ([`crate::chunk::adaptive::adaptive_partition`]) — matching the on-disk
/// index order of the in-core path.
pub struct ContainerWriter<W: Write> {
    sink: W,
    dtype: u8,
    field_shape: Vec<usize>,
    tau_abs: f64,
    block_shape: Vec<usize>,
    policy: TilingPolicy,
    inner: Option<Method>,
    entries: Vec<BlockEntry>,
    spool: Spool,
    spooled_bytes: usize,
}

impl<W: Write> ContainerWriter<W> {
    /// Writer whose blobs are buffered in memory until finalize. `policy`
    /// is the tiling policy the container records (it decides the
    /// serialized sub-version; see `docs/FORMAT.md`).
    pub fn in_memory<T: Scalar>(
        sink: W,
        field_shape: &[usize],
        tau_abs: f64,
        block_shape: Vec<usize>,
        policy: TilingPolicy,
    ) -> Self {
        ContainerWriter {
            sink,
            dtype: T::DTYPE_TAG,
            field_shape: field_shape.to_vec(),
            tau_abs,
            block_shape,
            policy,
            inner: None,
            entries: Vec::new(),
            spool: Spool::Mem(Vec::new()),
            spooled_bytes: 0,
        }
    }

    /// Writer whose blobs are spooled to a fresh temporary file under
    /// `spool_dir` (created if absent), keeping memory bounded regardless
    /// of the compressed size.
    pub fn spooled<T: Scalar>(
        sink: W,
        field_shape: &[usize],
        tau_abs: f64,
        block_shape: Vec<usize>,
        policy: TilingPolicy,
        spool_dir: &Path,
    ) -> Result<Self> {
        fs::create_dir_all(spool_dir)?;
        let path = spool_dir.join(format!(
            "mgardp_spool_{}_{}.blob",
            std::process::id(),
            SPOOL_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let file = fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)?;
        let mut w = Self::in_memory::<T>(sink, field_shape, tau_abs, block_shape, policy);
        w.spool = Spool::File { file, path };
        Ok(w)
    }

    /// Number of blocks pushed so far.
    pub fn blocks_written(&self) -> usize {
        self.entries.len()
    }

    /// Append one compressed block. `blob` must be a complete
    /// self-describing container of a non-chunked inner method; the first
    /// push fixes the container's inner-method tag and every later blob
    /// must match it.
    pub fn push_block(
        &mut self,
        start: &[usize],
        shape: &[usize],
        nlevels: usize,
        blob: &[u8],
    ) -> Result<()> {
        if start.len() != self.field_shape.len() || shape.len() != self.field_shape.len() {
            return Err(Error::shape("pushed block rank mismatch"));
        }
        for d in 0..shape.len() {
            if start[d] + shape[d] > self.field_shape[d] {
                return Err(Error::shape(format!(
                    "pushed block [{start:?} + {shape:?}) outside field {:?}",
                    self.field_shape
                )));
            }
        }
        let method = peek_method(blob)?;
        if method == Method::Chunked {
            return Err(Error::invalid(
                "nested chunked compressors are not supported",
            ));
        }
        match self.inner {
            None => self.inner = Some(method),
            Some(m) if m == method => {}
            Some(m) => {
                return Err(Error::invalid(format!(
                    "pushed {method:?} blob into a container of {m:?} blobs"
                )))
            }
        }
        self.entries.push(BlockEntry {
            offset: self.spooled_bytes,
            len: blob.len(),
            start: start.to_vec(),
            shape: shape.to_vec(),
            nlevels,
            tau_abs: self.tau_abs,
        });
        self.spool.write_all(blob)?;
        self.spooled_bytes += blob.len();
        crate::obs::inc(crate::obs::Ctr::StreamBlocks);
        Ok(())
    }

    /// Write the back-patched prefix (header + index + section length) to
    /// the sink, stream the spooled blobs after it, and return the sink
    /// together with the total container size in bytes.
    pub fn finalize(mut self) -> Result<(W, u64)> {
        let inner = self
            .inner
            .ok_or_else(|| Error::invalid("cannot finalize a container with no blocks"))?;
        // hand the accumulated index to the shared prefix serializer (the
        // same code path `write_container` uses, guaranteeing byte
        // identity with the in-core chunked compressor)
        let index = ChunkIndex {
            inner,
            block_shape: std::mem::take(&mut self.block_shape),
            policy: self.policy.clone(),
            entries: std::mem::take(&mut self.entries),
        };
        let mut prefix = Vec::with_capacity(64 + 64 * index.entries.len());
        index.write_prefix(
            &mut prefix,
            self.dtype,
            &self.field_shape,
            self.tau_abs,
            self.spooled_bytes,
        );
        self.sink.write_all(&prefix)?;
        match &mut self.spool {
            Spool::Mem(v) => self.sink.write_all(v)?,
            Spool::File { file, .. } => {
                file.flush()?;
                file.seek(SeekFrom::Start(0))?;
                let copied = io::copy(file, &mut self.sink)?;
                if copied != self.spooled_bytes as u64 {
                    return Err(Error::corrupt(format!(
                        "spool copy moved {copied} bytes, expected {}",
                        self.spooled_bytes
                    )));
                }
            }
        }
        self.sink.flush()?;
        let total = prefix.len() as u64 + self.spooled_bytes as u64;
        Ok((self.sink, total))
    }

    /// The parsed-form index accumulated so far (for diagnostics/tests).
    pub fn index(&self) -> Option<ChunkIndex> {
        self.inner.map(|inner| ChunkIndex {
            inner,
            block_shape: self.block_shape.clone(),
            policy: self.policy.clone(),
            entries: self.entries.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::container;
    use crate::compressors::Header;

    fn blobs() -> Vec<Vec<u8>> {
        // two tiny but well-formed inner containers (method MgardPlus)
        let mk = |shape: &[usize], payload: &[u8]| {
            let mut b = Vec::new();
            Header {
                method: Method::MgardPlus,
                dtype: 1,
                shape: shape.to_vec(),
                tau_abs: 0.5,
            }
            .write(&mut b);
            b.extend_from_slice(payload);
            b
        };
        vec![mk(&[8, 8], b"aaa"), mk(&[9, 8], b"zz")]
    }

    fn reference_container(blobs: &[Vec<u8>]) -> Vec<u8> {
        let entries = vec![
            BlockEntry {
                offset: 0,
                len: blobs[0].len(),
                start: vec![0, 0],
                shape: vec![8, 8],
                nlevels: 2,
                tau_abs: 0.5,
            },
            BlockEntry {
                offset: blobs[0].len(),
                len: blobs[1].len(),
                start: vec![8, 0],
                shape: vec![9, 8],
                nlevels: 3,
                tau_abs: 0.5,
            },
        ];
        container::write_container::<f32>(
            &[17, 8],
            0.5,
            &ChunkIndex {
                inner: Method::MgardPlus,
                block_shape: vec![8, 8],
                policy: TilingPolicy::Fixed,
                entries,
            },
            blobs,
        )
    }

    #[test]
    fn incremental_writer_matches_write_container_bytes() {
        let blobs = blobs();
        let want = reference_container(&blobs);
        for spooled in [false, true] {
            let dir = std::env::temp_dir().join(format!(
                "mgardp_writer_{}_{spooled}",
                std::process::id()
            ));
            let mut w = if spooled {
                ContainerWriter::spooled::<f32>(
                    Vec::new(),
                    &[17, 8],
                    0.5,
                    vec![8, 8],
                    TilingPolicy::Fixed,
                    &dir,
                )
                .unwrap()
            } else {
                ContainerWriter::in_memory::<f32>(
                    Vec::new(),
                    &[17, 8],
                    0.5,
                    vec![8, 8],
                    TilingPolicy::Fixed,
                )
            };
            w.push_block(&[0, 0], &[8, 8], 2, &blobs[0]).unwrap();
            w.push_block(&[8, 0], &[9, 8], 3, &blobs[1]).unwrap();
            assert_eq!(w.blocks_written(), 2);
            let (got, total) = w.finalize().unwrap();
            assert_eq!(got, want, "spooled={spooled}");
            assert_eq!(total as usize, want.len());
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn spool_file_removed_after_finalize_and_on_drop() {
        let dir = std::env::temp_dir().join(format!("mgardp_writer_rm_{}", std::process::id()));
        let blobs = blobs();
        let mut w = ContainerWriter::spooled::<f32>(
            Vec::<u8>::new(),
            &[17, 8],
            0.5,
            vec![8, 8],
            TilingPolicy::Fixed,
            &dir,
        )
        .unwrap();
        w.push_block(&[0, 0], &[8, 8], 2, &blobs[0]).unwrap();
        w.push_block(&[8, 0], &[9, 8], 3, &blobs[1]).unwrap();
        w.finalize().unwrap();
        // abandoned writer: spool cleaned up by Drop
        let mut w2 = ContainerWriter::spooled::<f32>(
            Vec::<u8>::new(),
            &[17, 8],
            0.5,
            vec![8, 8],
            TilingPolicy::Fixed,
            &dir,
        )
        .unwrap();
        w2.push_block(&[0, 0], &[8, 8], 2, &blobs[0]).unwrap();
        drop(w2);
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .map(|rd| rd.filter_map(|e| e.ok()).collect())
            .unwrap_or_default();
        assert!(leftovers.is_empty(), "spool files leaked: {leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writer_rejects_bad_blocks() {
        let blobs = blobs();
        let mut w = ContainerWriter::in_memory::<f32>(
            Vec::<u8>::new(),
            &[17, 8],
            0.5,
            vec![8, 8],
            TilingPolicy::Fixed,
        );
        // out-of-field block
        assert!(w.push_block(&[10, 0], &[9, 8], 2, &blobs[0]).is_err());
        // garbage blob (no header)
        assert!(w.push_block(&[0, 0], &[8, 8], 2, b"junk").is_err());
        // nested chunked blob
        let mut nested = Vec::new();
        Header {
            method: Method::Chunked,
            dtype: 1,
            shape: vec![8, 8],
            tau_abs: 0.5,
        }
        .write(&mut nested);
        assert!(w.push_block(&[0, 0], &[8, 8], 2, &nested).is_err());
        // no blocks -> finalize refuses
        assert!(w.finalize().is_err());
    }
}
