//! Streaming, out-of-core chunked compression and decompression.
//!
//! The chunked pipeline ([`crate::chunk`]) tiles a field into blocks and
//! compresses them in parallel — but its `compress` entry point needs the
//! whole field in core. This module removes that cap for simulation-scale
//! fields (the paper's §VI evaluates multi-GB snapshots):
//!
//! * [`BlockSource`] abstracts where blocks come from: an in-core tensor
//!   ([`InCoreSource`]) or a raw file on disk read one strided slab at a
//!   time ([`RawFileSource`]).
//! * [`compress_to_writer`] drives the worker pool under a configurable
//!   [`StreamConfig::memory_budget`]: at most `window` blocks are in flight
//!   (read but not yet written out), enforced by backpressure in
//!   [`crate::chunk::pool::parallel_map_ordered`].
//! * [`ContainerWriter`] streams compressed blobs to any [`std::io::Write`]
//!   sink and back-patches the chunk index at finalize.
//! * [`StreamingDecompressor`] mirrors the writer: it parses only the
//!   header + index, then decodes blocks on demand — the whole field to a
//!   raw-file sink, or just a sub-domain via `decompress_region`.
//!
//! Invariants:
//!
//! * **Ordered-window backpressure** — workers stall instead of reading
//!   ahead once `window` blocks are in flight, and results reach the
//!   writer in tile-list order regardless of completion order
//!   ([`crate::chunk::pool::parallel_map_ordered`]).
//! * **Byte identity** — the streamed container is **byte-identical** to
//!   the one the in-core [`crate::chunk::ChunkedCompressor`] produces for
//!   the same input, tiling configuration and tolerance, for both fixed
//!   and adaptive layouts — the two paths cross-check each other
//!   (enforced in `rust/tests/streaming.rs` and
//!   `rust/tests/adaptive_tiling.rs`).
//! * **Budget from the actual tile list** — the in-flight window is sized
//!   from the largest block the tiling *actually produced* (remainder-
//!   merged and adaptive blocks can both exceed the nominal shape), so an
//!   adaptive layout cannot overshoot [`StreamConfig::memory_budget`].
//!
//! ```
//! use mgardp::chunk::ChunkedConfig;
//! use mgardp::compressors::{MgardPlus, Tolerance};
//! use mgardp::stream::{compress_to_writer, InCoreSource, StreamConfig, StreamingDecompressor};
//! let field = mgardp::data::synth::smooth_test_field(&[12, 12]);
//! let cfg = StreamConfig {
//!     chunk: ChunkedConfig { block_shape: vec![8], threads: 1, ..Default::default() },
//!     memory_budget: 4096,
//!     spool_dir: None,
//! };
//! let mut bytes = Vec::new();
//! compress_to_writer(
//!     &MgardPlus::default(),
//!     &InCoreSource::new(&field),
//!     Tolerance::Rel(1e-3),
//!     &cfg,
//!     &mut bytes,
//! )
//! .unwrap();
//! let mut d = StreamingDecompressor::open(std::io::Cursor::new(bytes)).unwrap();
//! let back: mgardp::tensor::Tensor<f32> = d.decompress().unwrap();
//! assert_eq!(back.shape(), field.shape());
//! ```

pub mod reader;
pub mod source;
pub mod writer;

pub use reader::StreamingDecompressor;
pub use source::{BlockSource, InCoreSource, RawFileSource};
pub use writer::ContainerWriter;

use crate::chunk::pool::parallel_map_ordered_with;
use crate::chunk::{plan_tiles, resolve_block_shape, ChunkedConfig};
use crate::compressors::{Compressor, Tolerance};
use crate::error::{Error, Result};
use crate::grid::Hierarchy;
use crate::tensor::{numel, Scalar};
use std::io::Write;
use std::path::PathBuf;

/// Configuration of the streaming pipeline.
#[derive(Clone, Debug, Default)]
pub struct StreamConfig {
    /// Block shape and worker threads, exactly as in the in-core chunked
    /// path (single-entry shapes broadcast to the field rank).
    pub chunk: ChunkedConfig,
    /// Approximate cap, in bytes, on the raw data held in flight: the
    /// number of concurrently resident blocks is
    /// `max(1, memory_budget / (2 × largest_block_bytes))`, sized from the
    /// largest block of the *actual* tile list — remainder-merged blocks
    /// exceed the nominal shape, and an adaptive layout
    /// ([`crate::chunk::Tiling::Adaptive`]) can keep a smooth region as
    /// one block far larger than either (a factor 2 covers the raw slab
    /// plus its compressed blob; codec workspace is excluded). `0` means
    /// unbounded — every block may be in flight at once. The window never
    /// drops below one block, so a budget smaller than the largest tile
    /// still makes progress while holding that one tile resident.
    pub memory_budget: usize,
    /// Directory for the blob spool file; `None` buffers compressed blobs
    /// in memory (fine when the *compressed* size fits comfortably).
    pub spool_dir: Option<PathBuf>,
}

/// Resolve a byte budget to an in-flight block window given the largest
/// *actual* block of the tile list in elements. Sizing from the nominal
/// shape would overshoot the budget: remainder-merged blocks can be up to
/// ~2× bigger per dimension, and adaptive tiles are unbounded by the
/// nominal shape altogether (a smooth region stays one large block).
pub fn window_for_budget<T: Scalar>(
    memory_budget: usize,
    max_block_numel: usize,
    nblocks: usize,
) -> usize {
    if memory_budget == 0 {
        return nblocks.max(1);
    }
    let per_block = 2 * max_block_numel * T::BYTES;
    (memory_budget / per_block.max(1)).clamp(1, nblocks.max(1))
}

/// Compress `source` block-at-a-time with `inner`, streaming the chunked
/// container to `sink`. Returns the total container size in bytes.
///
/// Semantics match [`Compressor::compress`] on a
/// [`crate::chunk::ChunkedCompressor`] exactly —
/// the tolerance is resolved once against the whole field's value range and
/// every block is encoded at that absolute τ — and the emitted bytes are
/// identical to the in-core path's for the same input. Peak memory is
/// bounded by the in-flight window (see [`StreamConfig::memory_budget`])
/// plus the spool copy buffer, never the field or the blob section.
pub fn compress_to_writer<T, C, S, W>(
    inner: &C,
    source: &S,
    tol: Tolerance,
    cfg: &StreamConfig,
    sink: W,
) -> Result<u64>
where
    T: Scalar,
    C: Compressor<T> + Sync + ?Sized,
    S: BlockSource<T> + ?Sized,
    W: Write,
{
    // an absolute tolerance never consults the value range, so skip the
    // full-field min/max scan (a whole extra I/O pass on a RawFileSource)
    let tau = match tol {
        Tolerance::Abs(t) => t,
        Tolerance::Rel(_) => tol.absolute(source.value_range()?),
    };
    if tau <= 0.0 {
        return Err(Error::invalid("tolerance must be positive"));
    }
    let field_shape = source.shape().to_vec();
    let block_shape = resolve_block_shape(&cfg.chunk.block_shape, field_shape.len())?;
    // the variance pass of an adaptive tiling reads each min-shape cell
    // once through the same strided block reads the compression pass uses,
    // so it works unchanged on an out-of-core source
    let (blocks, policy) = plan_tiles(
        &field_shape,
        &block_shape,
        &cfg.chunk.tiling,
        cfg.chunk.threads,
        |b| source.read_block(&b.start, &b.shape),
    )?;
    // size the in-flight window from the largest tile the plan actually
    // produced — never the nominal shape — so heterogeneous (adaptive)
    // layouts stay inside the budget too
    let max_block_numel = blocks.iter().map(|b| numel(&b.shape)).max().unwrap_or(1);
    let window = window_for_budget::<T>(cfg.memory_budget, max_block_numel, blocks.len());
    let mut writer = match &cfg.spool_dir {
        Some(dir) => ContainerWriter::spooled::<T>(
            sink,
            &field_shape,
            tau,
            block_shape.clone(),
            policy,
            dir,
        )?,
        None => {
            ContainerWriter::in_memory::<T>(sink, &field_shape, tau, block_shape.clone(), policy)
        }
    };
    // one CodecScratch per worker (see chunk::ChunkedCompressor::compress):
    // warm buffers are reused across every block a worker compresses, so
    // the steady-state allocation count per block is O(1) here too
    parallel_map_ordered_with(
        blocks.len(),
        cfg.chunk.threads,
        window,
        crate::compressors::CodecScratch::<T>::new,
        |scratch, i| {
            let b = &blocks[i];
            let sub = source.read_block(&b.start, &b.shape)?;
            let bytes = inner.compress_scratch(&sub, Tolerance::Abs(tau), scratch)?;
            let nlevels = Hierarchy::new(&b.shape, None)?.nlevels();
            Ok((bytes, nlevels))
        },
        |i, (bytes, nlevels)| {
            let b = &blocks[i];
            writer.push_block(&b.start, &b.shape, nlevels, &bytes)
        },
    )?;
    let (_sink, total) = writer.finalize()?;
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::MgardPlus;
    use crate::data::synth;

    #[test]
    fn window_resolution() {
        // 16³-element f32 blocks are 16 KiB raw, 32 KiB with the in-flight
        // factor
        let w = window_for_budget::<f32>(256 * 1024, 16 * 16 * 16, 100);
        assert_eq!(w, 8);
        // budget below one block still makes progress
        assert_eq!(window_for_budget::<f32>(1, 16 * 16 * 16, 100), 1);
        // zero budget = unbounded
        assert_eq!(window_for_budget::<f32>(0, 16 * 16 * 16, 100), 100);
        // window never exceeds the block count
        assert_eq!(window_for_budget::<f32>(usize::MAX, 16, 3), 3);
    }

    #[test]
    fn vec_sink_matches_in_core_chunked_compress() {
        let t = synth::smooth_test_field(&[21, 22, 23]);
        let codec = MgardPlus::default().chunked(ChunkedConfig {
            block_shape: vec![10],
            threads: 2,
            ..Default::default()
        });
        let want = codec.compress(&t, Tolerance::Rel(1e-3)).unwrap();
        let mut got = Vec::new();
        let cfg = StreamConfig {
            chunk: ChunkedConfig {
                block_shape: vec![10],
                threads: 2,
                ..Default::default()
            },
            memory_budget: 64 * 1024, // well below the 388 KiB field
            spool_dir: None,
        };
        let src = InCoreSource::new(&t);
        let total =
            compress_to_writer(&MgardPlus::default(), &src, Tolerance::Rel(1e-3), &cfg, &mut got)
                .unwrap();
        assert_eq!(got, want);
        assert_eq!(total as usize, want.len());
    }

    #[test]
    fn invalid_tolerance_rejected() {
        let t = synth::smooth_test_field(&[8, 8]);
        let src = InCoreSource::new(&t);
        let r = compress_to_writer(
            &MgardPlus::default(),
            &src,
            Tolerance::Abs(0.0),
            &StreamConfig::default(),
            Vec::<u8>::new(),
        );
        assert!(r.is_err());
    }
}
