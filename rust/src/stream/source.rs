//! Where streamed blocks come from: an in-core tensor or a raw file on
//! disk read one strided slab at a time.

use crate::data::io;
use crate::error::{Error, Result};
use crate::tensor::{numel, Scalar, Tensor};
use std::fs;
use std::marker::PhantomData;
use std::path::{Path, PathBuf};

/// A field that can hand out one block at a time.
///
/// The streaming compressor never asks for more than the blocks inside its
/// in-flight window, so an implementation backed by external storage keeps
/// peak memory proportional to the window, not the field. Implementations
/// must be `Sync`: blocks are read concurrently from pool workers.
pub trait BlockSource<T: Scalar>: Sync {
    /// Shape of the whole field.
    fn shape(&self) -> &[usize];

    /// `max − min` over the whole field, used to resolve a relative
    /// tolerance to the absolute τ every block is encoded at. Must be
    /// computed exactly as [`Tensor::value_range`] so the streamed
    /// container is byte-identical to the in-core one.
    fn value_range(&self) -> Result<f64>;

    /// Read the block `[start, start + shape)` into a dense tensor.
    fn read_block(&self, start: &[usize], shape: &[usize]) -> Result<Tensor<T>>;
}

/// [`BlockSource`] over a tensor already in memory. Exists so the streaming
/// writer path can be cross-checked byte-for-byte against the in-core
/// chunked path on the same input.
pub struct InCoreSource<'a, T: Scalar> {
    data: &'a Tensor<T>,
}

impl<'a, T: Scalar> InCoreSource<'a, T> {
    /// Wrap a borrowed tensor.
    pub fn new(data: &'a Tensor<T>) -> Self {
        InCoreSource { data }
    }
}

impl<T: Scalar> BlockSource<T> for InCoreSource<'_, T> {
    fn shape(&self) -> &[usize] {
        self.data.shape()
    }

    fn value_range(&self) -> Result<f64> {
        Ok(self.data.value_range())
    }

    fn read_block(&self, start: &[usize], shape: &[usize]) -> Result<Tensor<T>> {
        self.data.block(start, shape)
    }
}

/// [`BlockSource`] over a headerless little-endian raw file (the SDRBench
/// layout [`crate::data::io`] already reads whole): each block is fetched
/// with per-run `seek`/`read`, so fields larger than RAM compress under a
/// fixed memory budget. Every call opens its own file handle, which keeps
/// concurrent reads from pool workers coordination-free.
pub struct RawFileSource<T: Scalar> {
    path: PathBuf,
    shape: Vec<usize>,
    _elem: PhantomData<T>,
}

impl<T: Scalar> RawFileSource<T> {
    /// Open `path` as a field of `shape`, validating the file size against
    /// the shape up front.
    pub fn new(path: &Path, shape: &[usize]) -> Result<Self> {
        if shape.is_empty() || shape.contains(&0) {
            return Err(Error::invalid(format!("bad raw field shape {shape:?}")));
        }
        let expect = (numel(shape) * T::BYTES) as u64;
        let actual = fs::metadata(path)?.len();
        if actual != expect {
            return Err(Error::invalid(format!(
                "{} is {actual} bytes; shape {shape:?} needs {expect}",
                path.display()
            )));
        }
        Ok(RawFileSource {
            path: path.to_path_buf(),
            shape: shape.to_vec(),
            _elem: PhantomData,
        })
    }

    /// The backing file path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl<T: Scalar> BlockSource<T> for RawFileSource<T> {
    fn shape(&self) -> &[usize] {
        &self.shape
    }

    fn value_range(&self) -> Result<f64> {
        let mut f = fs::File::open(&self.path)?;
        let (mn, mx) = io::raw_min_max::<T, _>(&mut f, numel(&self.shape))?;
        Ok(mx.to_f64() - mn.to_f64())
    }

    fn read_block(&self, start: &[usize], shape: &[usize]) -> Result<Tensor<T>> {
        let mut f = fs::File::open(&self.path)?;
        io::read_raw_block(&mut f, &self.shape, start, shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn raw_file_source_mirrors_in_core_source() {
        let dir = std::env::temp_dir().join(format!("mgardp_src_{}", std::process::id()));
        let t = synth::smooth_test_field(&[9, 12, 7]);
        let path = dir.join("field.f32");
        io::write_raw(&path, &t).unwrap();

        let file_src = RawFileSource::<f32>::new(&path, &[9, 12, 7]).unwrap();
        let core_src = InCoreSource::new(&t);
        assert_eq!(file_src.shape(), core_src.shape());
        // identical fold order -> bitwise-equal value range
        assert_eq!(
            file_src.value_range().unwrap(),
            core_src.value_range().unwrap()
        );
        let a = file_src.read_block(&[2, 3, 1], &[5, 6, 4]).unwrap();
        let b = core_src.read_block(&[2, 3, 1], &[5, 6, 4]).unwrap();
        assert_eq!(a, b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn raw_file_source_validates_size_and_shape() {
        let dir = std::env::temp_dir().join(format!("mgardp_src_bad_{}", std::process::id()));
        let t = synth::smooth_test_field(&[4, 4]);
        let path = dir.join("small.f32");
        io::write_raw(&path, &t).unwrap();
        assert!(RawFileSource::<f32>::new(&path, &[4, 5]).is_err());
        assert!(RawFileSource::<f64>::new(&path, &[4, 4]).is_err());
        assert!(RawFileSource::<f32>::new(&path, &[]).is_err());
        assert!(RawFileSource::<f32>::new(&dir.join("absent.f32"), &[4, 4]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
