//! Fuzz/property suite for the serve wire protocol — the adversarial
//! companion of `storage_serve.rs`. Two layers:
//!
//! * **decode fuzz** — randomized truncation, byte corruption, foreign
//!   magic, oversize length prefixes and trailing garbage against
//!   `protocol.rs` decoding: every case returns a structured error,
//!   never a panic, never an unbounded allocation.
//! * **live-daemon fuzz** — the same hostile inputs written to a real
//!   in-process server socket: every case is answered with a structured
//!   error frame or a clean close, never a hang (each case runs under a
//!   hard socket timeout) and never a daemon crash — the daemon must
//!   still serve a well-formed request afterwards.

use mgardp::coordinator::refactor::RefactorStore;
use mgardp::data::rng::Rng;
use mgardp::data::synth;
use mgardp::serve::protocol::{
    parse_response, read_frame, write_frame, Request, ServeStats, MAX_FRAME_BYTES, SERVE_MAGIC,
    SERVE_RESP_ERR, SERVE_RESP_OK,
};
use mgardp::serve::{ServeClient, ServeConfig, Server};
use mgardp::storage::MemoryStorage;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

/// Hard per-case timeout on every socket wait: a hostile input may be
/// answered or dropped, but it must never hang the harness.
const CASE_TIMEOUT: Duration = Duration::from_secs(10);

fn all_requests() -> Vec<Request> {
    vec![
        Request::Manifest,
        Request::Plan {
            tau: 0.25,
            floor: None,
        },
        Request::Plan {
            tau: 1e-4,
            floor: Some(vec![3, 1, 0, 2]),
        },
        Request::Fetch { stream: 2, comp: 5 },
        Request::Retrieve {
            tau: 0.5,
            region: None,
        },
        Request::Retrieve {
            tau: 0.01,
            region: Some(vec![(1, 7), (0, 9)]),
        },
        Request::Stats,
        Request::Metrics,
        Request::Shutdown,
    ]
}

// ---------------------------------------------------------------- decode

#[test]
fn every_truncation_of_every_request_errors() {
    for req in all_requests() {
        let p = req.encode();
        for cut in 0..p.len() {
            assert!(Request::decode(&p[..cut]).is_err(), "{req:?} cut at {cut}");
        }
        // and the full payload still round-trips
        assert_eq!(Request::decode(&p).unwrap(), req);
    }
}

#[test]
fn randomized_corruption_never_panics() {
    let mut rng = Rng::new(0x5EAF_00D5);
    let reqs = all_requests();
    for trial in 0..4000 {
        let mut p = reqs[rng.below(reqs.len())].encode();
        // flip 1..4 random bytes
        for _ in 0..(1 + rng.below(4)) {
            let i = rng.below(p.len());
            p[i] ^= (1 + rng.below(255)) as u8;
        }
        // decoding must return — Ok for a benign flip (e.g. inside tau's
        // bit pattern) or a structured Err — and must never panic
        let _ = Request::decode(&p);
        let _ = Request::decode_versioned(&p);
        if trial % 4 == 0 {
            // response-side parsing under the same corruption
            let _ = parse_response(&p);
            let _ = ServeStats::decode(&p);
        }
    }
}

#[test]
fn foreign_magic_and_garbage_rejected() {
    let mut rng = Rng::new(0xBAD_CAFE);
    for _ in 0..500 {
        let n = rng.below(64);
        let garbage: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        if garbage.len() >= 4 && &garbage[..4] == SERVE_MAGIC {
            continue; // astronomically unlikely; skip rather than assert
        }
        assert!(Request::decode(&garbage).is_err());
    }
    for magic in [b"MGRP", b"HTTP", b"\0\0\0\0", b"MGSW"] {
        let mut p = Request::Stats.encode();
        p[..4].copy_from_slice(magic);
        assert!(Request::decode(&p).is_err(), "{magic:?}");
    }
}

#[test]
fn oversize_declarations_refused_before_allocation() {
    // a frame length past the cap is refused by read_frame
    let mut framed = Vec::new();
    framed.extend_from_slice(&(MAX_FRAME_BYTES + 1).to_le_bytes());
    assert!(read_frame(&mut &framed[..]).is_err());
    // interior length fields (floor len, region rank) past their caps are
    // refused by decode without allocating the declared amount
    for (req, tail_patch) in [
        (
            Request::Plan {
                tau: 1.0,
                floor: None,
            },
            u64::MAX,
        ),
        (
            Request::Retrieve {
                tau: 1.0,
                region: None,
            },
            u64::MAX / 2,
        ),
    ] {
        let mut p = req.encode();
        let n = p.len();
        p[n - 8..].copy_from_slice(&tail_patch.to_le_bytes());
        assert!(Request::decode(&p).is_err(), "{req:?}");
    }
}

#[test]
fn huge_declared_indices_never_silently_truncate() {
    // decode uses checked u64 → usize conversion (`WireReader::usize`):
    // a declared index above u32::MAX must either round-trip to exactly
    // the declared value (64-bit targets) or fail with a structured
    // error (32-bit targets) — never alias a small index via `as usize`
    // truncation. The frame is patched at the byte level so the test is
    // meaningful even where `usize` cannot represent the value.
    let stream_decl = (1u64 << 40) | 0x1234;
    let comp_decl = (1u64 << 41) | 0x5678;
    let mut p = Request::Fetch { stream: 0, comp: 0 }.encode();
    let n = p.len();
    p[n - 16..n - 8].copy_from_slice(&stream_decl.to_le_bytes());
    p[n - 8..].copy_from_slice(&comp_decl.to_le_bytes());
    match Request::decode(&p) {
        Ok(Request::Fetch { stream, comp }) => {
            assert_eq!(stream as u64, stream_decl, "stream index truncated");
            assert_eq!(comp as u64, comp_decl, "comp index truncated");
        }
        Ok(other) => panic!("decoded the patched Fetch as {other:?}"),
        Err(_) => assert!(
            usize::try_from(stream_decl).is_err(),
            "a target whose usize holds the value must decode it"
        ),
    }
}

#[test]
fn live_daemon_answers_out_of_range_fetch_with_err() {
    // a Fetch whose (checked-decoded) indices are far outside the
    // manifest is answered with a structured ERR frame — the huge index
    // must reach the range check intact, not wrap into a valid one
    let server = start_server();
    let mut stream = connect(&server);
    let mut p = Request::Fetch { stream: 0, comp: 0 }.encode();
    let n = p.len();
    p[n - 16..n - 8].copy_from_slice(&((1u64 << 40) + 2).to_le_bytes());
    p[n - 8..].copy_from_slice(&((1u64 << 41) + 5).to_le_bytes());
    write_frame(&mut stream, &p).unwrap();
    let resp = read_frame(&mut stream).unwrap().expect("an ERR frame");
    assert_eq!(resp[0], SERVE_RESP_ERR);
    assert!(parse_response(&resp).is_err());
    // the same connection still serves a good request afterwards
    write_frame(&mut stream, &Request::Stats.encode()).unwrap();
    let resp = read_frame(&mut stream).unwrap().expect("stats after err");
    assert_eq!(resp[0], SERVE_RESP_OK);
    assert_still_serving(&server);
}

#[test]
fn trailing_garbage_rejected_on_every_op() {
    let mut rng = Rng::new(0x7A11);
    for req in all_requests() {
        let mut p = req.encode();
        for _ in 0..(1 + rng.below(9)) {
            p.push(rng.below(256) as u8);
        }
        assert!(Request::decode(&p).is_err(), "{req:?}");
    }
}

// ----------------------------------------------------------- live daemon

fn start_server() -> Server {
    let t = synth::smooth_test_field(&[17, 18]);
    let store = RefactorStore::with_storage(Arc::new(MemoryStorage::new()));
    store.write_field_progressive("u", &t, None, 3).unwrap();
    let field = store.progressive("u").unwrap();
    Server::start(
        field,
        &ServeConfig {
            // tight mid-frame stall bound so slow-loris cases resolve fast
            request_timeout_ms: 500,
            ..ServeConfig::default()
        },
    )
    .unwrap()
}

fn connect(server: &Server) -> TcpStream {
    let s = TcpStream::connect(server.addr()).unwrap();
    s.set_read_timeout(Some(CASE_TIMEOUT)).unwrap();
    s.set_write_timeout(Some(CASE_TIMEOUT)).unwrap();
    s
}

/// The daemon still answers a well-formed request — the proof that a
/// hostile case neither crashed nor wedged it.
fn assert_still_serving(server: &Server) {
    let mut client = ServeClient::connect(server.addr()).unwrap();
    let stats = client.stats().unwrap();
    assert!(stats.requests > 0 || stats.connections > 0, "{stats:?}");
}

#[test]
fn live_daemon_survives_corrupt_frames() {
    let server = start_server();
    let mut rng = Rng::new(0xD00D);
    let reqs = all_requests();
    for trial in 0..40 {
        let mut stream = connect(&server);
        // a corrupted (but complete) frame must be answered with a
        // structured ERR frame on the same connection
        let mut p = reqs[rng.below(reqs.len() - 1)].encode(); // never Shutdown
        match trial % 3 {
            0 => p[rng.below(4)] ^= (1 + rng.below(255)) as u8, // break the magic
            1 => p[4] = 4 + rng.below(252) as u8,               // unknown version
            _ => p[5] = 8 + rng.below(248) as u8,               // unknown op
        }
        write_frame(&mut stream, &p).unwrap();
        match read_frame(&mut stream).unwrap() {
            Some(resp) => {
                assert_eq!(resp[0], SERVE_RESP_ERR, "trial {trial}: {resp:?}");
                assert!(parse_response(&resp).is_err());
            }
            None => panic!("trial {trial}: daemon closed instead of answering"),
        }
        // the same connection still serves a good request afterwards
        write_frame(&mut stream, &Request::Stats.encode()).unwrap();
        let resp = read_frame(&mut stream).unwrap().expect("stats after err");
        assert_eq!(resp[0], SERVE_RESP_OK);
    }
    assert_still_serving(&server);
}

#[test]
fn live_daemon_survives_truncated_frames_and_garbage() {
    let server = start_server();
    let mut rng = Rng::new(0xFEED);
    for trial in 0..30 {
        let mut stream = connect(&server);
        match trial % 3 {
            0 => {
                // a frame header promising more than we send, then close:
                // the daemon must drop the connection, not hang
                let p = Request::Stats.encode();
                let mut framed = Vec::new();
                framed.extend_from_slice(&(p.len() as u32 + 7).to_le_bytes());
                framed.extend_from_slice(&p);
                stream.write_all(&framed).unwrap();
            }
            1 => {
                // raw garbage that never forms a complete frame header
                let n = 1 + rng.below(3);
                let garbage: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
                stream.write_all(&garbage).unwrap();
            }
            _ => {
                // a plausible frame full of garbage: answered with ERR or
                // dropped — either is structured, neither may hang
                let n = 6 + rng.below(32);
                let garbage: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
                write_frame(&mut stream, &garbage).unwrap();
            }
        }
        // reading must resolve (frame, clean close, or reset) within the
        // case timeout — a hang here fails the whole test binary
        let mut buf = [0u8; 256];
        let _ = stream.read(&mut buf);
        drop(stream);
    }
    assert_still_serving(&server);
}

#[test]
fn live_daemon_refuses_oversize_length_prefix() {
    let server = start_server();
    let mut stream = connect(&server);
    // declare just past the frame cap; send nothing else
    stream
        .write_all(&(MAX_FRAME_BYTES + 1).to_le_bytes())
        .unwrap();
    // the daemon drops the connection (it cannot answer reliably): the
    // read must resolve to EOF/reset within the timeout, never hang
    let mut buf = [0u8; 64];
    let n = stream.read(&mut buf).unwrap_or(0);
    assert_eq!(n, 0, "expected a close, got {n} bytes");
    assert_still_serving(&server);
}

#[test]
fn live_daemon_rejects_trailing_garbage_in_frame() {
    let server = start_server();
    let mut stream = connect(&server);
    let mut p = Request::Manifest.encode();
    p.extend_from_slice(&[1, 2, 3]);
    write_frame(&mut stream, &p).unwrap();
    let resp = read_frame(&mut stream).unwrap().expect("an ERR frame");
    assert_eq!(resp[0], SERVE_RESP_ERR);
    assert_still_serving(&server);
}

#[test]
fn metrics_op_is_refused_below_version_3() {
    // SERVE_OP_METRICS is version-windowed: the same frame with the
    // version byte downgraded to 2 (or 1) must decode-fail, and a live
    // daemon must answer it with a structured ERR frame, not a hang
    for version in [1u8, 2] {
        let mut p = Request::Metrics.encode();
        p[4] = version;
        assert!(Request::decode(&p).is_err(), "v{version}");
        assert!(Request::decode_versioned(&p).is_err(), "v{version}");
    }
    let server = start_server();
    let mut stream = connect(&server);
    let mut p = Request::Metrics.encode();
    p[4] = 2;
    write_frame(&mut stream, &p).unwrap();
    let resp = read_frame(&mut stream).unwrap().expect("an ERR frame");
    assert_eq!(resp[0], SERVE_RESP_ERR);
    assert!(parse_response(&resp).is_err());
    assert_still_serving(&server);
}

#[test]
fn live_daemon_rejects_malformed_metrics_frames_but_answers_v3() {
    let server = start_server();
    // trailing garbage on a metrics frame is refused
    let mut stream = connect(&server);
    let mut p = Request::Metrics.encode();
    p.extend_from_slice(&[0xAA, 0x55]);
    write_frame(&mut stream, &p).unwrap();
    let resp = read_frame(&mut stream).unwrap().expect("an ERR frame");
    assert_eq!(resp[0], SERVE_RESP_ERR);
    // a truncated metrics frame (header only, op byte cut off) is refused
    let mut stream = connect(&server);
    let p = Request::Metrics.encode();
    write_frame(&mut stream, &p[..5]).unwrap();
    let resp = read_frame(&mut stream).unwrap().expect("an ERR frame");
    assert_eq!(resp[0], SERVE_RESP_ERR);
    // and the well-formed v3 request is answered with the exposition text
    let mut stream = connect(&server);
    write_frame(&mut stream, &Request::Metrics.encode()).unwrap();
    let resp = read_frame(&mut stream).unwrap().expect("an OK frame");
    assert_eq!(resp[0], SERVE_RESP_OK);
    let body = parse_response(&resp).unwrap();
    let text = std::str::from_utf8(body).expect("metrics body is UTF-8");
    assert!(text.lines().any(|l| l.starts_with("counter serve.requests ")), "{text}");
    assert!(text.lines().any(|l| l.starts_with("hist serve.request ")), "{text}");
    assert_still_serving(&server);
}

#[test]
fn live_daemon_answers_version_1_clients() {
    let server = start_server();
    let mut stream = connect(&server);
    let mut p = Request::Stats.encode();
    p[4] = 1; // downgrade to protocol version 1
    write_frame(&mut stream, &p).unwrap();
    let resp = read_frame(&mut stream).unwrap().unwrap();
    let body = parse_response(&resp).unwrap();
    assert_eq!(body.len(), 9 * 8, "v1 stats body");
    let stats = ServeStats::decode(body).unwrap();
    assert_eq!(stats.refused, 0);
    assert_still_serving(&server);
}
