//! Differential property suite for the shared component cache — the
//! in-tree port of the `validate_pr7.py` stamp-LRU oracle. A randomized
//! op sequence runs against `ComponentCache` and an ordered-map
//! reference model in lockstep; every divergence in hit/miss outcome,
//! eviction count, occupancy or recency order is a failure. On top of
//! the sequential oracle, targeted races pin the single-flight
//! invariants: exactly one backend fetch per concurrent miss stampede,
//! eviction racing an in-flight fetch, the oversize bypass under
//! concurrency, and leader-failure fallback.

use mgardp::data::rng::Rng;
use mgardp::error::Error;
use mgardp::storage::ComponentCache;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Duration;

/// Ordered-map reference model of a byte-capacity stamp-LRU: a list of
/// `(key, len)` in recency order (least recent first) plus counters.
struct Reference {
    capacity: u64,
    /// key -> payload length; recency tracked in `order`.
    entries: BTreeMap<String, u64>,
    /// least-recently-used first.
    order: Vec<String>,
    bytes_used: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl Reference {
    fn new(capacity: u64) -> Reference {
        Reference {
            capacity,
            entries: BTreeMap::new(),
            order: Vec::new(),
            bytes_used: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    fn touch(&mut self, key: &str) {
        if let Some(i) = self.order.iter().position(|k| k == key) {
            let k = self.order.remove(i);
            self.order.push(k);
        }
    }

    fn get(&mut self, key: &str) -> bool {
        if self.entries.contains_key(key) {
            self.hits += 1;
            self.touch(key);
            true
        } else {
            self.misses += 1;
            false
        }
    }

    fn insert(&mut self, key: &str, len: u64) {
        if len > self.capacity {
            return; // oversize bypass
        }
        if let Some(old) = self.entries.remove(key) {
            self.bytes_used -= old;
            self.order.retain(|k| k != key);
        }
        while self.bytes_used + len > self.capacity {
            let victim = self.order.remove(0);
            let gone = self.entries.remove(&victim).unwrap();
            self.bytes_used -= gone;
            self.evictions += 1;
        }
        self.entries.insert(key.to_string(), len);
        self.order.push(key.to_string());
        self.bytes_used += len;
    }
}

/// Payload for `key` of length `len`, content derived from both so a
/// wrong payload is caught by value, not just by length.
fn payload(key: &str, len: usize) -> Vec<u8> {
    let tag = key.bytes().fold(0u8, u8::wrapping_add);
    vec![tag ^ (len as u8); len]
}

#[test]
fn randomized_ops_match_the_reference_model() {
    for seed in [0x1A7E_u64, 0xC0DE, 0x5109] {
        let mut rng = Rng::new(seed);
        let capacity = 64 + rng.below(192) as u64;
        let cache = ComponentCache::new(capacity);
        let mut reference = Reference::new(capacity);
        for step in 0..3000 {
            let key = format!("k{}", rng.below(24));
            match rng.below(10) {
                // plain lookup: outcome must match the model exactly
                0..=3 => {
                    let expect = reference.get(&key);
                    let got = cache.get(&key);
                    assert_eq!(got.is_some(), expect, "seed {seed:#x} step {step} get {key}");
                }
                // insert: sizes cross the capacity (oversize bypass) and
                // force evictions
                4..=6 => {
                    let len = 1 + rng.below(capacity as usize + capacity as usize / 4);
                    cache.insert(&key, Arc::new(payload(&key, len)));
                    reference.insert(&key, len as u64);
                }
                // get_or_fetch: counts one hit or one miss like get+insert
                _ => {
                    let len = 1 + rng.below(capacity as usize / 2);
                    let expect_hit = reference.get(&key);
                    if !expect_hit {
                        reference.insert(&key, len as u64);
                    }
                    let v = cache
                        .get_or_fetch(&key, || Ok(payload(&key, len)))
                        .unwrap();
                    if !expect_hit {
                        assert_eq!(*v, payload(&key, len), "seed {seed:#x} step {step}");
                    }
                }
            }
            // invariants + full state equivalence after every op
            let s = cache.stats();
            assert!(s.bytes_used <= capacity);
            assert_eq!(s.hits, reference.hits, "seed {seed:#x} step {step}");
            assert_eq!(s.misses, reference.misses, "seed {seed:#x} step {step}");
            assert_eq!(s.evictions, reference.evictions, "seed {seed:#x} step {step}");
            assert_eq!(s.bytes_used, reference.bytes_used, "seed {seed:#x} step {step}");
            assert_eq!(s.entries as usize, reference.entries.len());
            assert_eq!(
                cache.keys_by_recency(),
                reference.order,
                "seed {seed:#x} step {step}: recency order diverged"
            );
        }
    }
}

#[test]
fn stampede_on_one_key_issues_exactly_one_fetch() {
    const CLIENTS: usize = 12;
    let cache = Arc::new(ComponentCache::new(1 << 16));
    let fetches = Arc::new(AtomicUsize::new(0));
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let cache = Arc::clone(&cache);
            let fetches = Arc::clone(&fetches);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let v = cache
                    .get_or_fetch("hot", || {
                        fetches.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(Duration::from_millis(40));
                        Ok(payload("hot", 64))
                    })
                    .unwrap();
                assert_eq!(*v, payload("hot", 64));
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(fetches.load(Ordering::SeqCst), 1, "single-flight violated");
    let s = cache.stats();
    assert_eq!(s.misses, 1);
    assert_eq!(s.hits, (CLIENTS - 1) as u64);
    assert_eq!(s.coalesced, (CLIENTS - 1) as u64);
}

#[test]
fn eviction_during_inflight_fetch_is_safe() {
    // capacity of 100 bytes; a slow fetch of `cold` (60 bytes) runs while
    // another thread churns the cache hard enough to evict everything
    // repeatedly — the waiter must still get the right payload, and the
    // cache must stay within capacity throughout
    let cache = Arc::new(ComponentCache::new(100));
    cache.insert("seed0", Arc::new(payload("seed0", 40)));
    let gate = Arc::new(Barrier::new(3));
    let cold_leader = {
        let cache = Arc::clone(&cache);
        let gate = Arc::clone(&gate);
        std::thread::spawn(move || {
            let v = cache
                .get_or_fetch("cold", || {
                    gate.wait(); // churn + waiter start only once in flight
                    std::thread::sleep(Duration::from_millis(60));
                    Ok(payload("cold", 60))
                })
                .unwrap();
            assert_eq!(*v, payload("cold", 60));
        })
    };
    let churn = {
        let cache = Arc::clone(&cache);
        let gate = Arc::clone(&gate);
        std::thread::spawn(move || {
            gate.wait();
            for i in 0..200 {
                let key = format!("churn{}", i % 5);
                cache.insert(&key, Arc::new(payload(&key, 30)));
                assert!(cache.stats().bytes_used <= 100);
            }
        })
    };
    // a waiter that joins the in-flight fetch mid-churn
    gate.wait();
    let v = cache
        .get_or_fetch("cold", || {
            panic!("waiter must coalesce onto the in-flight fetch")
        })
        .unwrap();
    assert_eq!(*v, payload("cold", 60));
    cold_leader.join().unwrap();
    churn.join().unwrap();
    let s = cache.stats();
    assert!(s.coalesced >= 1, "{s:?}");
    assert!(s.bytes_used <= 100);
}

#[test]
fn oversize_bypass_race_serves_waiters_but_caches_nothing() {
    // payload larger than the whole capacity: the leader and every waiter
    // receive the bytes, but nothing is inserted and nothing is evicted
    const CLIENTS: usize = 6;
    let cache = Arc::new(ComponentCache::new(32));
    cache.insert("resident", Arc::new(payload("resident", 16)));
    let fetches = Arc::new(AtomicUsize::new(0));
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let cache = Arc::clone(&cache);
            let fetches = Arc::clone(&fetches);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let v = cache
                    .get_or_fetch("huge", || {
                        fetches.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(Duration::from_millis(20));
                        Ok(payload("huge", 64))
                    })
                    .unwrap();
                assert_eq!(v.len(), 64);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // single-flight still coalesces the stampede itself; the payload is
    // handed to all waiters without ever entering the cache
    assert_eq!(fetches.load(Ordering::SeqCst), 1);
    let s = cache.stats();
    assert_eq!(s.evictions, 0, "oversize payload must not evict: {s:?}");
    assert!(cache.get("huge").is_none());
    assert!(cache.get("resident").is_some(), "resident entry survived");
}

#[test]
fn failed_leader_does_not_poison_the_key() {
    let cache = Arc::new(ComponentCache::new(1 << 12));
    let attempts = Arc::new(AtomicUsize::new(0));
    // serial: a failed fetch leaves the key fetchable
    let r = cache.get_or_fetch("k", || {
        Err::<Vec<u8>, _>(Error::transient("backend down"))
    });
    assert!(matches!(r, Err(Error::Transient(_))));
    let v = cache.get_or_fetch("k", || Ok(payload("k", 8))).unwrap();
    assert_eq!(*v, payload("k", 8));
    // concurrent: leader fails while waiters are parked; every waiter is
    // eventually served by a successor leader
    const CLIENTS: usize = 8;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let cache = Arc::clone(&cache);
            let attempts = Arc::clone(&attempts);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                cache.get_or_fetch("flaky", || {
                    let n = attempts.fetch_add(1, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(15));
                    if n == 0 {
                        Err(Error::transient("first leader dies"))
                    } else {
                        Ok(payload("flaky", 16))
                    }
                })
            })
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(results.iter().filter(|r| r.is_err()).count(), 1);
    assert_eq!(results.iter().filter(|r| r.is_ok()).count(), CLIENTS - 1);
    for r in results.into_iter().flatten() {
        assert_eq!(*r, payload("flaky", 16));
    }
    assert_eq!(attempts.load(Ordering::SeqCst), 2, "failed + successful leader");
}
