//! Integration suite for the in-tree observability layer (PR 9).
//!
//! Three properties pinned here are load-bearing for the whole design:
//!
//! * **Lock-free snapshot consistency** — `registry::snapshot()` taken
//!   while writer threads hammer the cells is monotone and internally
//!   consistent (a histogram's derived count only ever counts
//!   observations the snapshot actually saw), and the final delta is
//!   exact once the writers join.
//! * **Quantile bounds** — the log2-bucket estimate always brackets the
//!   sorted-vector oracle: `oracle ≤ estimate < 2·max(oracle, 1)`.
//! * **Value transparency** — compressed containers (in-core, chunked,
//!   streamed) and progressive store objects are byte-identical with
//!   telemetry enabled or disabled: the subsystem reads clocks and bumps
//!   atomics but never touches data.

use mgardp::coordinator::cli::run;
use mgardp::data::rng::Rng;
use mgardp::obs::{self, registry, Ctr, Gg, Hist};
use std::path::{Path, PathBuf};

fn s(v: &[&str]) -> Vec<String> {
    v.iter().map(|x| x.to_string()).collect()
}

// ------------------------------------------------------------- registry

#[test]
fn snapshot_is_consistent_under_concurrent_writers() {
    // record straight into the cells (bypassing the enabled gate) so the
    // test needs no coordination with the global telemetry flag
    let before = registry::snapshot();
    const WRITERS: u64 = 4;
    const PER_WRITER: u64 = 50_000;
    let handles: Vec<_> = (0..WRITERS)
        .map(|w| {
            std::thread::spawn(move || {
                for i in 0..PER_WRITER {
                    registry::counter(Ctr::ServeRefused).add(1);
                    registry::hist(Hist::ServeDecode).record((w * 31 + i) % 10_000);
                }
            })
        })
        .collect();
    // snapshot continuously while the writers run: counts are monotone
    // and every mid-flight snapshot supports quantile derivation
    let mut last_count = before.hist(Hist::ServeDecode).count();
    let mut last_ctr = before.counter(Ctr::ServeRefused);
    while handles.iter().any(|h| !h.is_finished()) {
        let snap = registry::snapshot();
        let count = snap.hist(Hist::ServeDecode).count();
        let ctr = snap.counter(Ctr::ServeRefused);
        assert!(count >= last_count, "{count} < {last_count}");
        assert!(ctr >= last_ctr, "{ctr} < {last_ctr}");
        let p99 = snap.hist(Hist::ServeDecode).quantile(0.99);
        assert!(count == 0 || p99 <= registry::bucket_upper_bound(registry::NUM_BUCKETS - 1));
        last_count = count;
        last_ctr = ctr;
    }
    for h in handles {
        h.join().unwrap();
    }
    // once the writers join, the delta is exact — no lost updates
    let d = registry::snapshot().delta(&before);
    assert_eq!(d.counter(Ctr::ServeRefused), WRITERS * PER_WRITER);
    assert_eq!(d.hist(Hist::ServeDecode).count(), WRITERS * PER_WRITER);
}

#[test]
fn quantile_estimates_bracket_the_sorted_oracle() {
    let mut rng = Rng::new(0x0B5E_55ED);
    for trial in 0..60 {
        let h = registry::Histogram::new();
        let n = 1 + rng.below(400);
        let mut values = Vec::with_capacity(n);
        for _ in 0..n {
            // span many magnitudes, hitting 0 and the bucket edges hard
            let exp = rng.below(40) as u32;
            let v = match rng.below(4) {
                0 => 0u64,
                1 => 1u64 << exp,
                2 => (1u64 << exp) - 1,
                _ => (1u64 << exp) + rng.below(1 << 16) as u64,
            };
            values.push(v);
            h.record(v);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count(), n as u64);
        assert_eq!(snap.sum_ns, values.iter().sum::<u64>());
        values.sort_unstable();
        for q in [0.5, 0.9, 0.95, 0.99] {
            let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
            let oracle = values[rank - 1];
            let est = snap.quantile(q);
            assert!(est >= oracle, "trial {trial} q={q}: {est} < {oracle}");
            assert!(
                est < 2 * oracle.max(1),
                "trial {trial} q={q}: {est} >= 2·max({oracle}, 1)"
            );
        }
    }
}

#[test]
fn exposition_covers_the_whole_catalog() {
    let text = registry::snapshot().render();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(
        lines.len(),
        Ctr::ALL.len() + Gg::ALL.len() + Hist::ALL.len(),
        "one line per catalog entry"
    );
    // catalog order: counters, then gauges, then histograms
    for (i, id) in Ctr::ALL.iter().enumerate() {
        assert!(lines[i].starts_with(&format!("counter {} ", id.name())), "{}", lines[i]);
    }
    for (i, id) in Gg::ALL.iter().enumerate() {
        let line = lines[Ctr::ALL.len() + i];
        assert!(line.starts_with(&format!("gauge {} ", id.name())), "{line}");
    }
    for (i, id) in Hist::ALL.iter().enumerate() {
        let line = lines[Ctr::ALL.len() + Gg::ALL.len() + i];
        assert!(line.starts_with(&format!("hist {} ", id.name())), "{line}");
        assert_eq!(line.split(' ').count(), 7, "{line}");
        // every span name resolves back to its histogram id
        assert_eq!(registry::hist_by_name(id.name()), Some(*id));
    }
}

// ----------------------------------------------------- value transparency

/// Every file under `root`, as sorted (relative-path, bytes) pairs.
fn dir_bytes(root: &Path) -> Vec<(String, Vec<u8>)> {
    fn walk(dir: &Path, rel: &str, out: &mut Vec<(String, Vec<u8>)>) {
        for e in std::fs::read_dir(dir).unwrap() {
            let e = e.unwrap();
            let name = e.file_name().to_string_lossy().to_string();
            let key = if rel.is_empty() {
                name
            } else {
                format!("{rel}/{name}")
            };
            if e.file_type().unwrap().is_dir() {
                walk(&e.path(), &key, out);
            } else {
                out.push((key, std::fs::read(e.path()).unwrap()));
            }
        }
    }
    let mut out = Vec::new();
    walk(root, "", &mut out);
    out.sort();
    out
}

#[test]
fn containers_are_byte_identical_with_telemetry_on_and_off() {
    let was = obs::enabled();
    let dir = std::env::temp_dir().join(format!("mgardp_obs_ident_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let raw = dir.join("in.f32");
    let t = mgardp::data::synth::smooth_test_field(&[17, 18, 19]);
    mgardp::data::io::write_raw(&raw, &t).unwrap();

    // one compress run per (path, telemetry) cell, all through the real
    // CLI so the --telemetry flag itself is exercised
    let compress = |tag: &str, on: bool, extra: &[&str]| -> Vec<u8> {
        let out = dir.join(format!("{tag}_{on}.mgrp"));
        let mut argv = s(&[
            "--input",
            raw.to_str().unwrap(),
            "--shape",
            "17x18x19",
            "--output",
            out.to_str().unwrap(),
            "--rel",
            "1e-3",
            "--telemetry",
            if on { "true" } else { "false" },
        ]);
        argv.extend(s(extra));
        run("compress", &argv).unwrap();
        std::fs::read(&out).unwrap()
    };
    // in-core single-tensor path
    assert_eq!(
        compress("incore", true, &[]),
        compress("incore", false, &[]),
        "in-core container differs under telemetry"
    );
    // chunked parallel path (worker pool + per-block spans active)
    let chunked = ["--block-shape", "8x8x8", "--threads", "2"];
    assert_eq!(
        compress("chunked", true, &chunked),
        compress("chunked", false, &chunked),
        "chunked container differs under telemetry"
    );
    // out-of-core streamed path (stream writer + spool + backpressure)
    let streamed = [
        "--block-shape",
        "8x8x8",
        "--threads",
        "2",
        "--stream",
        "--memory-budget",
        "16K",
    ];
    let on_bytes = compress("streamed", true, &streamed);
    assert_eq!(
        on_bytes,
        compress("streamed", false, &streamed),
        "streamed container differs under telemetry"
    );

    // decompressed raw output is likewise identical either way
    let rec_of = |on: bool| -> Vec<u8> {
        let cont = dir.join(format!("streamed_{on}.mgrp"));
        let rec = dir.join(format!("rec_{on}.f32"));
        run(
            "decompress",
            &s(&[
                "--input",
                cont.to_str().unwrap(),
                "--output",
                rec.to_str().unwrap(),
                "--stream",
                "--telemetry",
                if on { "true" } else { "false" },
            ]),
        )
        .unwrap();
        std::fs::read(&rec).unwrap()
    };
    assert_eq!(rec_of(true), rec_of(false));

    // progressive refactor store: every stored object byte-identical
    let store_of = |on: bool| -> Vec<(String, Vec<u8>)> {
        let store = dir.join(format!("store_{on}"));
        run(
            "refactor",
            &s(&[
                "--input",
                raw.to_str().unwrap(),
                "--shape",
                "17x18x19",
                "--store",
                store.to_str().unwrap(),
                "--field",
                "T",
                "--progressive",
                "--telemetry",
                if on { "true" } else { "false" },
            ]),
        )
        .unwrap();
        dir_bytes(&store)
    };
    assert_eq!(store_of(true), store_of(false), "progressive store differs");

    obs::set_enabled(was);
    std::fs::remove_dir_all(&dir).ok();
}
