//! The contract every compressor in the crate must honour: the reconstructed
//! data never deviates from the original by more than the requested L∞
//! tolerance — on smooth data, rough data, adversarial data, and all four
//! synthetic dataset analogs.

use mgardp::compressors::{all_compressors, Tolerance};
use mgardp::data::{rng::Rng, synth};
use mgardp::metrics::linf_error;
use mgardp::tensor::Tensor;

fn check_all(data: &Tensor<f32>, rel: f64, label: &str) {
    // same degenerate-range fallback as Tolerance::absolute
    let range = data.value_range();
    let tau = rel * if range > 0.0 { range } else { 1.0 };
    for c in all_compressors::<f32>() {
        let bytes = c
            .compress(data, Tolerance::Rel(rel))
            .unwrap_or_else(|e| panic!("{} failed on {label}: {e}", c.name()));
        let back = c
            .decompress(&bytes)
            .unwrap_or_else(|e| panic!("{} decompress failed on {label}: {e}", c.name()));
        assert_eq!(back.shape(), data.shape());
        let err = linf_error(data.data(), back.data());
        assert!(
            err <= tau * (1.0 + 1e-6),
            "{} violates bound on {label}: err {err} > τ {tau}",
            c.name()
        );
    }
}

#[test]
fn synthetic_dataset_fields_bounded() {
    // small-scale versions of all four dataset analogs
    for ds in synth::all_datasets(0.12, 7) {
        for f in &ds.fields {
            check_all(&f.data, 1e-3, &format!("{}/{}", ds.name, f.name));
        }
    }
}

#[test]
fn tolerance_sweep_on_smooth_field() {
    let t = synth::smooth_test_field(&[20, 18, 22]);
    for rel in [1e-1, 1e-2, 1e-3, 1e-4] {
        check_all(&t, rel, &format!("smooth rel={rel}"));
    }
}

#[test]
fn white_noise_bounded() {
    let mut rng = Rng::new(3);
    let t = Tensor::<f32>::from_fn(&[17, 15, 13], |_| rng.uniform_in(-1.0, 1.0) as f32);
    check_all(&t, 1e-2, "white noise");
}

#[test]
fn constant_field_bounded() {
    let t = Tensor::<f32>::from_fn(&[12, 12, 12], |_| 3.25);
    check_all(&t, 1e-3, "constant");
}

#[test]
fn step_discontinuity_bounded() {
    let t = Tensor::<f32>::from_fn(&[16, 16, 16], |ix| if ix[0] < 8 { -5.0 } else { 7.0 });
    check_all(&t, 1e-3, "step");
}

#[test]
fn large_magnitude_values_bounded() {
    let mut rng = Rng::new(9);
    let t = Tensor::<f32>::from_fn(&[14, 14, 14], |_| {
        (rng.uniform_in(-5.0, 12.0) as f32).exp() * 1e6
    });
    check_all(&t, 1e-3, "large magnitudes");
}

#[test]
fn alternating_checkerboard_bounded() {
    // worst case for interpolation-based prediction
    let t = Tensor::<f32>::from_fn(&[15, 15, 15], |ix| {
        if (ix[0] + ix[1] + ix[2]) % 2 == 0 {
            1.0
        } else {
            -1.0
        }
    });
    check_all(&t, 5e-2, "checkerboard");
}

#[test]
fn anisotropic_shapes_bounded() {
    let t = synth::smooth_test_field(&[6, 40, 11]);
    check_all(&t, 1e-3, "anisotropic");
    let t2 = synth::smooth_test_field(&[64, 7]);
    check_all(&t2, 1e-3, "2d anisotropic");
}

#[test]
fn four_dimensional_bounded() {
    let t = synth::smooth_test_field(&[5, 8, 9, 7]);
    check_all(&t, 1e-3, "4d");
}

#[test]
fn one_dimensional_bounded() {
    let t = synth::smooth_test_field(&[257]);
    check_all(&t, 1e-4, "1d");
}
