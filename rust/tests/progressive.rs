//! Tier-1 contract of the progressive multi-precision retrieval subsystem:
//!
//! * for a sweep of tolerances τ on 1/2/3-D synthetic fields, the planner's
//!   component set reconstructs within `‖u − ũ‖_∞ ≤ τ`, fetching strictly
//!   fewer bytes than the full refactored field whenever τ admits dropping
//!   at least one bitplane;
//! * incremental refinement is monotone (never re-fetches, never loosens)
//!   and reaches **bit-exact** lossless recovery once every component has
//!   been applied;
//! * PR-era (magic-less) level-layout stores remain readable next to the
//!   new versioned manifests.

use mgardp::coordinator::refactor::{FieldLayout, RefactorStore};
use mgardp::data::synth;
use mgardp::decompose::{Decomposer, OptFlags};
use mgardp::grid::Hierarchy;
use mgardp::metrics::linf_error;
use mgardp::progressive::{
    plan, plan_with_floor, refactor_streams, ProgressiveManifest, ProgressiveReader, StreamMeta,
};
use mgardp::quant::{level_tolerances, DEFAULT_C_LINF};
use mgardp::tensor::{numel, Tensor};

fn temp_store(tag: &str) -> RefactorStore {
    let dir = std::env::temp_dir().join(format!(
        "mgardp_progressive_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    RefactorStore::create(dir).unwrap()
}

/// The store's lossless reference: recomposing the exact decomposition.
fn lossless_reference(t: &Tensor<f32>) -> Tensor<f32> {
    let h = Hierarchy::new(t.shape(), None).unwrap();
    let dz = Decomposer::new(h, OptFlags::all()).unwrap();
    dz.recompose(&dz.decompose(t).unwrap()).unwrap()
}

fn planner_bound_sweep(shape: &[usize], tag: &str) {
    let store = temp_store(tag);
    let t = synth::smooth_test_field(shape);
    store.write_field_progressive("u", &t, None, 3).unwrap();
    let field = store.progressive("u").unwrap();
    let total = field.manifest().total_bytes();
    let range = t.value_range();
    for rel in [0.3, 0.1, 3e-2, 1e-2, 3e-3, 1e-3, 3e-4, 1e-4] {
        let tau = rel * range;
        let (back, plan): (Tensor<f32>, _) = field.retrieve(tau).unwrap();
        assert_eq!(back.shape(), t.shape());
        assert!(
            plan.certified_bound <= tau,
            "{shape:?} τ {tau}: certificate {}",
            plan.certified_bound
        );
        let err = linf_error(t.data(), back.data());
        assert!(
            err <= tau * (1.0 + 1e-6),
            "{shape:?} τ {tau}: L∞ {err} exceeds the bound"
        );
        // any τ loose enough to certify without everything must fetch less
        // than the whole refactored field
        let dropped_any = plan
            .per_stream
            .iter()
            .any(|&c| c < field.manifest().comps_per_stream());
        if dropped_any {
            assert!(
                plan.bytes < total,
                "{shape:?} τ {tau}: dropped components but fetched {} of {total}",
                plan.bytes
            );
        }
    }
    std::fs::remove_dir_all(store.root().unwrap()).ok();
}

#[test]
fn planner_bound_sweep_1d() {
    planner_bound_sweep(&[129], "1d");
}

#[test]
fn planner_bound_sweep_2d() {
    planner_bound_sweep(&[33, 17], "2d");
}

#[test]
fn planner_bound_sweep_3d() {
    // non-dyadic extents exercise padding under the hierarchy
    planner_bound_sweep(&[17, 18, 19], "3d");
}

#[test]
fn refinement_plans_are_byte_monotone() {
    // independent plans at different τ may differ slightly (the greedy
    // give-back is not globally optimal), but *refinement* — planning with
    // the already-fetched floor — is monotone by construction: it never
    // drops a held component and never re-fetches
    let t = synth::smooth_test_field(&[33, 33]);
    let (m, _) = refactor_streams(&t, 24, 3).unwrap();
    let range = t.value_range();
    let mut floor = vec![0usize; m.streams.len()];
    let mut prev = 0u64;
    for rel in [0.3, 0.1, 3e-2, 1e-2, 3e-3, 1e-3, 1e-5, 1e-9] {
        let p = plan_with_floor(&m, rel * range, Some(&floor)).unwrap();
        assert!(p.certified_bound <= rel * range);
        assert!(p.bytes >= prev, "rel {rel}: {} < {prev}", p.bytes);
        for (f, &c) in floor.iter_mut().zip(&p.per_stream) {
            assert!(c >= *f, "refinement dropped a held component");
            *f = c;
        }
        prev = p.bytes;
    }
    // and an absurdly tight τ degrades to lossless, certified at exactly 0
    let p = plan(&m, 1e-300).unwrap();
    assert!(p.is_lossless());
    assert_eq!(p.certified_bound, 0.0);
}

#[test]
fn refinement_to_all_planes_is_bit_exact_lossless() {
    for shape in [&[65][..], &[17, 18][..], &[9, 10, 11][..]] {
        let store = temp_store(&format!("lossless{}", shape.len()));
        let t = synth::smooth_test_field(shape);
        store.write_field_progressive("u", &t, None, 3).unwrap();
        let field = store.progressive("u").unwrap();
        let mut reader = field.reader::<f32>().unwrap();
        // refine through two progressively tighter plans, then to lossless
        let range = t.value_range();
        let mut fetched = 0u64;
        for tau in [0.1 * range, 1e-3 * range] {
            let p = field.plan(tau, Some(&reader.fetched())).unwrap();
            let delta = field.refine(&mut reader, &p).unwrap();
            fetched += delta;
            assert_eq!(fetched, reader.bytes_fetched(), "no re-fetching");
            let back = reader.reconstruct().unwrap();
            let err = linf_error(t.data(), back.data());
            assert!(err <= tau * (1.0 + 1e-6), "τ {tau}: {err}");
        }
        // the final step: an (unreachably tight) τ degrades to "fetch
        // everything", whose certificate — error 0 vs the store's lossless
        // reference — is checked bit-for-bit below
        let p = field.plan(f64::MIN_POSITIVE, Some(&reader.fetched())).unwrap();
        field.refine(&mut reader, &p).unwrap();
        assert_eq!(reader.current_bound(), 0.0);
        assert!(reader.is_lossless());
        assert_eq!(reader.bytes_fetched(), field.manifest().total_bytes());
        let exact = lossless_reference(&t);
        let back = reader.reconstruct().unwrap();
        assert_eq!(exact.shape(), back.shape());
        for (a, b) in exact.data().iter().zip(back.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "lossless must be bit-exact");
        }
        std::fs::remove_dir_all(store.root().unwrap()).ok();
    }
}

#[test]
fn f64_progressive_round_trip() {
    let store = temp_store("f64");
    let t32 = synth::smooth_test_field(&[17, 17]);
    let t = Tensor::<f64>::from_fn(t32.shape(), |ix| t32.at(ix) as f64);
    store.write_field_progressive("u", &t, None, 3).unwrap();
    let field = store.progressive("u").unwrap();
    let (back, plan): (Tensor<f64>, _) = field.retrieve(1e-6).unwrap();
    assert!(plan.certified_bound <= 1e-6);
    assert!(linf_error(t.data(), back.data()) <= 1e-6);
    // f32 readers are refused on an f64 field
    assert!(field.reader::<f32>().is_err());
    std::fs::remove_dir_all(store.root().unwrap()).ok();
}

#[test]
fn pr_era_level_store_remains_readable() {
    let store = temp_store("compat");
    let t = synth::smooth_test_field(&[17, 17]);
    let m = store.write_field("u", &t, 3).unwrap();
    // rewrite the manifest in the PR-era encoding: body only, no
    // magic/version header (what stores created before this PR contain)
    let manifest_path = store.root().unwrap().join("u").join("manifest.bin");
    let versioned = std::fs::read(&manifest_path).unwrap();
    assert_eq!(&versioned[..4], b"MGRF");
    std::fs::write(&manifest_path, &versioned[5..]).unwrap();
    assert_eq!(store.layout("u").unwrap(), FieldLayout::Level);
    assert_eq!(store.manifest("u").unwrap(), m);
    let back: Tensor<f32> = store.reconstruct("u", m.max_level).unwrap();
    assert!(linf_error(t.data(), back.data()) < 1e-4);
    std::fs::remove_dir_all(store.root().unwrap()).ok();
}

#[test]
fn coarse_only_and_zero_fetch_edge_cases() {
    let t = synth::smooth_test_field(&[33]);
    let (m, comps) = refactor_streams(&t, 24, 3).unwrap();
    // τ larger than the certified worst case: nothing needs fetching and
    // the all-zero reconstruction is still certified
    let worst: f64 = m.streams.iter().map(|s| s.max_abs).sum::<f64>() * m.c_linf;
    let p = plan(&m, worst * 2.0).unwrap();
    assert_eq!(p.bytes, 0);
    let reader: ProgressiveReader<f32> = ProgressiveReader::new(m.clone()).unwrap();
    let zeros = reader.reconstruct().unwrap();
    assert!(linf_error(t.data(), zeros.data()) <= reader.current_bound() * (1.0 + 1e-9));
    // sanity: the component payloads advertised by the manifest exist
    assert_eq!(comps.len(), m.streams.len());
}

// ---------------------------------------------------------------------------
// PR-4 adversarial planner regressions, ported from the Python-only
// validation harness: certificate-repair manifests whose error schedules sit
// *exactly* on the geometric (irrational-κ) allocation targets, and the
// τ→0 semantics of all-zero streams.
// ---------------------------------------------------------------------------

/// Smallest `e` with `v < 2^e` for positive normal `v` (bit-exact; no log2
/// rounding risk — mirrors `bitplane::exponent_above` for normal inputs).
fn exponent_above(v: f64) -> i32 {
    assert!(v > 0.0 && v.is_finite());
    let e = ((v.to_bits() >> 52) & 0x7FF) as i32 - 1022;
    // powers of two sit exactly on the boundary: 2^(e-1) has exponent e-1+1
    debug_assert!(v < 2f64.powi(e) && v >= 2f64.powi(e - 1));
    e
}

/// Build a fully valid manifest over `shape` whose per-stream error
/// schedules land **exactly** on the planner's phase-1 targets for `tau`:
/// the worst case for the certificate, because the float sum of the
/// selected bounds can exceed `tau / c_linf` by ulps (the pre-repair
/// planner returned certificates above τ for schedules like these).
fn adversarial_manifest(shape: &[usize], tau: f64) -> ProgressiveManifest {
    let h = Hierarchy::new(shape, None).unwrap();
    let d = shape.len();
    let nstreams = h.nlevels() + 1;
    let planes = 3usize;
    // bit-identical to the planner's own allocation (same fn, same args)
    let targets = level_tolerances(nstreams, d, tau, DEFAULT_C_LINF);
    let mut streams = Vec::with_capacity(nstreams);
    for (s, &t) in targets.iter().enumerate() {
        let n = if s == 0 {
            numel(&h.level_shape(0))
        } else {
            h.num_coeff_nodes(s)
        };
        let max_abs = t * 1.5;
        let err_after = vec![max_abs, max_abs, t, t * 0.5, t * 0.25, 0.0];
        let comp_lens: Vec<u64> = vec![1, 2, 2, 2, n as u64 * 4 + 1];
        streams.push(StreamMeta {
            n,
            max_abs,
            exponent: exponent_above(max_abs),
            comp_lens,
            err_after,
        });
    }
    ProgressiveManifest {
        shape: shape.to_vec(),
        dtype: 1,
        start_level: 0,
        max_level: h.nlevels(),
        planes,
        c_linf: DEFAULT_C_LINF,
        streams,
    }
}

#[test]
fn certificate_holds_exactly_on_irrational_kappa_targets() {
    // κ = √2 (1-D) and κ = √8 (3-D) are irrational, so every target is a
    // rounded double and the schedule sums are maximally ulp-hostile. On
    // IEEE-754 doubles several rungs of the 3-D ladder overflow the naive
    // phase-1 certificate by exactly 1 ulp (k = 2, 4, 6, 8 in the Python
    // mirror) — the repair pass must tighten those plans. The assertions
    // below don't hardcode which rungs overflow (that is
    // rounding-order-sensitive); they recompute the naive certificate
    // bit-identically and require `certified_bound <= tau` *exactly* in
    // every case, repair or not.
    for shape in [&[65usize][..], &[9, 9, 9][..]] {
        let d = shape.len();
        let kap = mgardp::quant::kappa(d);
        for k in -6..=10i32 {
            let tau = kap.powi(k) * 0.37;
            let m = adversarial_manifest(shape, tau);
            // the construction passes full manifest validation
            let round = ProgressiveManifest::from_bytes(&m.to_bytes()).unwrap();
            assert_eq!(round, m, "{shape:?} k={k}: manifest round trip");

            let p = plan(&m, tau).unwrap();
            assert!(
                p.certified_bound <= tau,
                "{shape:?} k={k}: certificate {} > τ {tau}",
                p.certified_bound
            );
            // determinism
            assert_eq!(p, plan(&m, tau).unwrap(), "{shape:?} k={k}: plan not deterministic");

            // recompute phase 1's naive selection bit-identically: first
            // admissible component per stream, summed in stream order
            let targets = level_tolerances(m.streams.len(), d, tau, m.c_linf);
            let naive: Vec<usize> = m
                .streams
                .iter()
                .zip(&targets)
                .map(|(sm, &t)| {
                    (0..=m.comps_per_stream())
                        .find(|&c| c != 1 && sm.err_after[c] <= t)
                        .unwrap_or(m.comps_per_stream())
                })
                .collect();
            let naive_cert: f64 = m.c_linf
                * naive
                    .iter()
                    .enumerate()
                    .map(|(s, &c)| m.streams[s].err_after[c])
                    .sum::<f64>();
            if naive_cert > tau {
                // the pre-fix planner would have returned this overflowing
                // certificate; the repair pass must have tightened at
                // least one stream beyond the naive selection
                assert!(
                    p.per_stream.iter().zip(&naive).any(|(a, b)| a > b),
                    "{shape:?} k={k}: naive certificate {naive_cert} > τ {tau} \
                     but no stream was tightened"
                );
            }
        }
    }
}

#[test]
fn all_zero_streams_are_never_fetched_even_as_tau_vanishes() {
    // manifest-level: a zero stream (max_abs = 0, flat zero schedule) costs
    // bytes to fetch but contributes no error — the planner must skip it at
    // *any* τ, so τ→0 plans are not byte-lossless even though their
    // certified bound is exactly 0 (the documented `is_lossless` nuance
    // from the PR-4 Python harness)
    let h = Hierarchy::new(&[65], None).unwrap();
    let mut m = adversarial_manifest(&[65], 1e-2);
    let z = 2; // turn stream 2 into an all-zero stream
    m.streams[z] = StreamMeta {
        n: h.num_coeff_nodes(z),
        max_abs: 0.0,
        exponent: 0,
        comp_lens: vec![1, 2, 2, 2, h.num_coeff_nodes(z) as u64 * 4 + 1],
        err_after: vec![0.0; 6],
    };
    let m = ProgressiveManifest::from_bytes(&m.to_bytes()).unwrap();
    for tau in [1e-2, 1e-9, 1e-30, 1e-300, f64::MIN_POSITIVE] {
        let p = plan(&m, tau).unwrap();
        assert_eq!(p.per_stream[z], 0, "τ {tau}: zero stream fetched");
        assert!(p.certified_bound <= tau);
    }
    let p = plan(&m, f64::MIN_POSITIVE).unwrap();
    assert_eq!(p.certified_bound, 0.0);
    // every nonzero stream is fully fetched, yet the plan is not
    // byte-lossless because the zero stream's stored bytes stay behind
    for (s, &c) in p.per_stream.iter().enumerate() {
        if s != z {
            assert_eq!(c, m.comps_per_stream(), "stream {s} not fully fetched");
        }
    }
    assert!(!p.is_lossless(), "τ→0 plan claims byte-losslessness");
    assert!(p.bytes < m.total_bytes());

    // end-to-end: an all-zero *field* refactors to all-zero streams; a
    // τ→0 retrieval fetches nothing and reconstructs exactly
    let t = Tensor::<f32>::zeros(&[17]);
    let (mz, _) = refactor_streams(&t, 8, 3).unwrap();
    let pz = plan(&mz, f64::MIN_POSITIVE).unwrap();
    assert_eq!(pz.bytes, 0, "zero field still fetched bytes");
    assert_eq!(pz.certified_bound, 0.0);
    let reader: ProgressiveReader<f32> = ProgressiveReader::new(mz).unwrap();
    let back = reader.reconstruct().unwrap();
    for (a, b) in t.data().iter().zip(back.data()) {
        assert_eq!(a.to_bits(), b.to_bits(), "zero-field reconstruction not exact");
    }
}

#[test]
fn stored_bytes_match_manifest_accounting() {
    let store = temp_store("accounting");
    let t = synth::smooth_test_field(&[17, 18]);
    let manifest = store.write_field_progressive("u", &t, Some(16), 3).unwrap();
    assert_eq!(manifest.planes, 16);
    let blob = std::fs::read(store.root().unwrap().join("u").join("components.bin")).unwrap();
    assert_eq!(blob.len() as u64, manifest.total_bytes());
    // every component range slices the blob exactly
    let field = store.progressive("u").unwrap();
    for (s, meta) in manifest.streams.iter().enumerate() {
        for c in 0..manifest.comps_per_stream() {
            let (off, len) = manifest.component_range(s, c).unwrap();
            let direct = &blob[off as usize..(off + len) as usize];
            let fetched = field
                .fetch_component(mgardp::progressive::ComponentId { stream: s, comp: c })
                .unwrap();
            assert_eq!(direct, fetched.as_slice());
        }
        assert_eq!(meta.comp_lens.len(), manifest.comps_per_stream());
    }
    std::fs::remove_dir_all(store.root().unwrap()).ok();
}
