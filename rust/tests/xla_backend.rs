//! Cross-layer consistency: the AOT-compiled XLA level step (Pallas + JAX,
//! lowered to HLO text and executed via PJRT) must agree with the native
//! Rust `decompose::contiguous` engine to f32 rounding.
//!
//! These tests are skipped (with a notice) when `make artifacts` has not
//! been run, so `cargo test` stays green in a bare checkout.

use mgardp::data::synth;
use mgardp::decompose::{Decomposer, OptFlags};
use mgardp::grid::Hierarchy;
use mgardp::metrics::linf_error;
use mgardp::runtime::{artifacts_dir, XlaLevelStep, XlaRuntime};
use mgardp::tensor::Tensor;

fn load_step(n: usize) -> Option<XlaLevelStep> {
    if !mgardp::runtime::pjrt_available() {
        eprintln!("skipping: PJRT runtime unavailable (see rust/src/runtime/pjrt.rs)");
        return None;
    }
    let dir = artifacts_dir();
    if !XlaLevelStep::available(&dir, n) {
        eprintln!("skipping: artifacts for n={n} not found (run `make artifacts`)");
        return None;
    }
    let rt = XlaRuntime::cpu().expect("PJRT CPU client");
    Some(XlaLevelStep::load(&rt, &dir, n).expect("load artifacts"))
}

fn native_one_step(u: &Tensor<f32>) -> (Tensor<f32>, Vec<f32>) {
    // a single decomposition step through the public API: cap the hierarchy
    // at one level
    let h = Hierarchy::new(u.shape(), Some(1)).unwrap();
    let dec = Decomposer::new(h, OptFlags::all()).unwrap();
    let d = dec.decompose(u).unwrap();
    assert_eq!(d.coeffs.len(), 1);
    (d.coarse.clone(), d.coeffs[0].clone())
}

#[test]
fn xla_matches_native_engine_n17() {
    let Some(step) = load_step(17) else { return };
    let u = synth::smooth_test_field(&[17, 17, 17]);
    let (xc, xs) = step.decompose(&u).unwrap();
    let (nc, ns) = native_one_step(&u);
    assert_eq!(xc.shape(), nc.shape());
    assert_eq!(xs.len(), ns.len());
    let cerr = linf_error(xc.data(), nc.data());
    let serr = linf_error(&xs, &ns);
    assert!(cerr < 1e-4, "coarse mismatch {cerr}");
    assert!(serr < 1e-4, "stream mismatch {serr}");
}

#[test]
fn xla_matches_native_engine_n33_random() {
    let Some(step) = load_step(33) else { return };
    let mut rng = mgardp::data::rng::Rng::new(17);
    let u = Tensor::<f32>::from_fn(&[33, 33, 33], |_| rng.uniform_in(-2.0, 2.0) as f32);
    let (xc, xs) = step.decompose(&u).unwrap();
    let (nc, ns) = native_one_step(&u);
    assert!(linf_error(xc.data(), nc.data()) < 1e-4);
    assert!(linf_error(&xs, &ns) < 1e-4);
}

#[test]
fn xla_round_trip_exact() {
    let Some(step) = load_step(17) else { return };
    let mut rng = mgardp::data::rng::Rng::new(23);
    let u = Tensor::<f32>::from_fn(&[17, 17, 17], |_| rng.uniform_in(-1.0, 1.0) as f32);
    let (coarse, stream) = step.decompose(&u).unwrap();
    let back = step.recompose(&coarse, &stream).unwrap();
    let err = linf_error(u.data(), back.data());
    assert!(err < 1e-5, "xla round trip {err}");
}

#[test]
fn xla_cross_recompose_with_native_decompose() {
    // native decompose -> xla recompose: the two implementations must be
    // interchangeable mid-pipeline
    let Some(step) = load_step(17) else { return };
    let u = synth::smooth_test_field(&[17, 17, 17]);
    let (nc, ns) = native_one_step(&u);
    let back = step.recompose(&nc, &ns).unwrap();
    let err = linf_error(u.data(), back.data());
    assert!(err < 1e-4, "cross recompose {err}");
}

#[test]
fn xla_rejects_wrong_shapes() {
    let Some(step) = load_step(17) else { return };
    let u = synth::smooth_test_field(&[9, 9, 9]);
    assert!(step.decompose(&u).is_err());
}
