//! Panel-width differential suite: the line-batched, cache-blocked sweep
//! engine (PR 6) must be **bit-identical** to the per-line engine for every
//! panel width.
//!
//! `DecomposeScratch::panel_width` is a pure tuning knob: width 1 forces the
//! per-line reference path, every other width (including widths beyond any
//! line count) batches the same per-element arithmetic in the same
//! association order. This suite pins that equivalence across
//!
//! * 1/2/3-D shapes, dyadic and non-dyadic (incl. 17×33×65),
//! * f32 and f64,
//! * every `OptFlags` ablation combination (pre-BCC combos must be inert
//!   to the knob; batched combos must be value-transparent in it),
//! * the staged and fused container paths through `CodecScratch`, and
//! * block shapes matching what the chunked/streamed workers compress
//!   (those workers construct default-width scratches internally, so
//!   pw-transparency at block shapes + the existing chunked/streamed
//!   byte-identity tests in `decompose_equivalence.rs` cover the full
//!   container matrix transitively).
//!
//! Equality is exact (`assert_eq!` on the scalar slices and on container
//! bytes), not tolerance-based: the batched kernels are bit-identical by
//! construction, and this suite is the enforcement.

use mgardp::compressors::{CodecScratch, Compressor, MgardPlus, MgardPlusConfig, Tolerance};
use mgardp::data::rng::Rng;
use mgardp::decompose::{DecomposeScratch, Decomposer, OptFlags, DEFAULT_PANEL_WIDTH};
use mgardp::grid::Hierarchy;
use mgardp::metrics::linf_error;
use mgardp::tensor::{Scalar, Tensor};

/// Panel widths under test: the per-line oracle (1), tiny odd widths that
/// exercise ragged tail panels, the production default, and a width larger
/// than every line count in the shape set.
const WIDTHS: [usize; 6] = [1, 2, 3, 5, DEFAULT_PANEL_WIDTH, 4096];

/// Shapes: 1/2/3-D, dyadic and non-dyadic, including the issue's 17×33×65.
fn shapes() -> Vec<Vec<usize>> {
    vec![
        vec![33],
        vec![16],
        vec![65],
        vec![17, 9],
        vec![12, 10],
        vec![33, 33],
        vec![9, 9, 9],
        vec![6, 10, 11],
        vec![17, 33, 65],
    ]
}

/// Flag combinations: the panel paths engage only with `batched`; pre-BCC
/// combos pin that the knob is inert there.
fn flag_combos() -> Vec<OptFlags> {
    vec![
        OptFlags::dr(),
        OptFlags::dr_dlvc(),
        OptFlags::dr_dlvc_bcc(),
        OptFlags::all_staged(),
        OptFlags::all(),
    ]
}

fn rand_f64(shape: &[usize], seed: u64) -> Tensor<f64> {
    let mut rng = Rng::new(seed);
    Tensor::from_fn(shape, |_| rng.uniform_in(-1.0, 1.0))
}

fn rand_f32(shape: &[usize], seed: u64) -> Tensor<f32> {
    let mut rng = Rng::new(seed);
    Tensor::from_fn(shape, |_| rng.uniform_in(-1.0, 1.0) as f32)
}

/// Decompose + recompose `u` at every panel width and assert exact equality
/// with the width-1 (per-line) result.
fn assert_panel_transparent<T: Scalar>(u: &Tensor<T>, flags: OptFlags, what: &str) {
    let h = Hierarchy::new(u.shape(), None).unwrap();
    let dec = Decomposer::new(h, flags).unwrap();
    let mut s1 = DecomposeScratch::<T>::with_panel_width(1);
    let reference = dec.decompose_scratch(u, &mut s1).unwrap();
    let back_ref = dec.recompose_scratch(&reference, &mut s1).unwrap();
    for pw in WIDTHS {
        if pw == 1 {
            continue;
        }
        let mut s = DecomposeScratch::<T>::with_panel_width(pw);
        let d = dec.decompose_scratch(u, &mut s).unwrap();
        assert_eq!(
            reference.coarse.data(),
            d.coarse.data(),
            "{what} pw={pw}: coarse"
        );
        assert_eq!(reference.coeffs, d.coeffs, "{what} pw={pw}: coefficient streams");
        let back = dec.recompose_scratch(&d, &mut s).unwrap();
        // exact bit comparison of the reconstructions via the LE encoding
        for (i, (a, b)) in back_ref.data().iter().zip(back.data()).enumerate() {
            let (mut xa, mut xb) = (Vec::new(), Vec::new());
            a.write_le(&mut xa);
            b.write_le(&mut xb);
            assert_eq!(xa, xb, "{what} pw={pw}: reconstruction bit {i}");
        }
    }
}

#[test]
fn panel_widths_bit_identical_f64_all_flags() {
    for (si, shape) in shapes().iter().enumerate() {
        let u = rand_f64(shape, 6000 + si as u64);
        for flags in flag_combos() {
            assert_panel_transparent(&u, flags, &format!("{shape:?} {flags:?} f64"));
        }
    }
}

#[test]
fn panel_widths_bit_identical_f32() {
    // single precision on the full shape set with the production flags
    // (batched paths engaged) plus one pre-BCC combo (knob inert)
    for (si, shape) in shapes().iter().enumerate() {
        let u = rand_f32(shape, 7000 + si as u64);
        for flags in [OptFlags::dr_dlvc(), OptFlags::all()] {
            assert_panel_transparent(&u, flags, &format!("{shape:?} {flags:?} f32"));
        }
    }
}

/// The container paths: compressing through a `CodecScratch` whose
/// `decompose.panel_width` is 1, the default, or over-wide must produce the
/// container bytes of the plain `compress` entry point — for the staged and
/// the fused engine, at field shapes and at worker block shapes.
#[test]
fn containers_byte_identical_across_panel_widths() {
    let tau = 1e-3;
    let cases: Vec<Vec<usize>> = vec![
        vec![33],
        vec![17, 33, 65],
        // worker block shapes (what the chunked/streamed pool compresses)
        vec![16, 16, 16],
        vec![16],
        vec![8, 12, 10],
    ];
    for (si, shape) in cases.iter().enumerate() {
        let u = rand_f32(shape, 8000 + si as u64);
        for (flags, adaptive) in [
            (OptFlags::all(), false),
            (OptFlags::all_staged(), false),
            (OptFlags::all(), true),
        ] {
            let m = MgardPlus::new(MgardPlusConfig {
                adaptive,
                flags,
                ..MgardPlusConfig::default()
            });
            let want = m.compress(&u, Tolerance::Abs(tau)).unwrap();
            for pw in [1usize, DEFAULT_PANEL_WIDTH, 4096] {
                let mut ws = CodecScratch::<f32>::new();
                ws.decompose.panel_width = pw;
                // twice through the same scratch: reuse must stay transparent
                for round in 0..2 {
                    let got = m.compress_scratch(&u, Tolerance::Abs(tau), &mut ws).unwrap();
                    assert_eq!(
                        want, got,
                        "{shape:?} {flags:?} adaptive={adaptive} pw={pw} round={round}"
                    );
                }
            }
            let back: Tensor<f32> = m.decompress(&want).unwrap();
            assert!(linf_error(u.data(), back.data()) <= tau * (1.0 + 1e-6));
        }
    }
}

/// Chunked and streamed containers of the same field must be byte-identical
/// regardless of the panel width the *plain* oracle used — pinning that the
/// worker pool's internal (default-width) scratches agree with the
/// per-line engine block by block.
#[test]
fn chunked_container_matches_per_line_oracle_blocks() {
    use mgardp::chunk::{ChunkedConfig, Tiling};
    let t = rand_f32(&[17, 33, 65], 9001);
    let tau = 1e-3;
    let cfg = MgardPlusConfig {
        adaptive: false,
        flags: OptFlags::all(),
        ..MgardPlusConfig::default()
    };
    let chunked = MgardPlus::new(cfg).chunked(ChunkedConfig {
        block_shape: vec![16],
        threads: 2,
        tiling: Tiling::Fixed,
    });
    let container = chunked.compress(&t, Tolerance::Abs(tau)).unwrap();
    // every block the pool compressed (default panel width) must equal the
    // per-line (pw = 1) compression of that block
    let m = MgardPlus::new(cfg);
    let mut ws = CodecScratch::<f32>::new();
    ws.decompose.panel_width = 1;
    for bz in (0..17).step_by(16) {
        for by in (0..33).step_by(16) {
            for bx in (0..65).step_by(16) {
                let bshape = [16.min(17 - bz), 16.min(33 - by), 16.min(65 - bx)];
                let block = t.block(&[bz, by, bx], &bshape).unwrap();
                let per_line = m
                    .compress_scratch(&block, Tolerance::Abs(tau), &mut ws)
                    .unwrap();
                let batched = m.compress(&block, Tolerance::Abs(tau)).unwrap();
                assert_eq!(
                    per_line, batched,
                    "block at [{bz},{by},{bx}]: per-line vs batched bytes"
                );
            }
        }
    }
    let back: Tensor<f32> = chunked.decompress(&container).unwrap();
    assert!(linf_error(t.data(), back.data()) <= tau * (1.0 + 1e-6));
}
