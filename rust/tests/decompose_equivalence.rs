//! Engine-equivalence suite: `decompose::baseline` (the original §2 method)
//! and `decompose::contiguous` (the §5-optimized engine) implement the same
//! transform, so their decompositions must agree to FP rounding across
//! every `OptFlags` ablation combination and across odd/even/1-d/2-d/3-d
//! shapes — and their outputs must be interchangeable at recompose time.

use mgardp::data::rng::Rng;
use mgardp::decompose::{Decomposer, OptFlags};
use mgardp::grid::Hierarchy;
use mgardp::metrics::{linf_error, value_range};
use mgardp::tensor::Tensor;

/// Every legal flag combination, baseline first (the Fig. 6 series plus the
/// non-cumulative DR+IVER variant).
fn all_flag_combos() -> Vec<OptFlags> {
    let mut combos = vec![
        OptFlags::baseline(),
        OptFlags::dr(),
        OptFlags::dr_dlvc(),
        OptFlags::dr_dlvc_bcc(),
        OptFlags::all(),
    ];
    combos.push(OptFlags {
        reorder: true,
        direct_load: false,
        batched: false,
        reuse: true,
    });
    combos.push(OptFlags {
        reorder: true,
        direct_load: true,
        batched: false,
        reuse: true,
    });
    combos
}

/// Shapes covering 1-d/2-d/3-d, odd and even extents, dyadic and non-dyadic.
fn shapes() -> Vec<Vec<usize>> {
    vec![
        vec![17],
        vec![16],
        vec![33],
        vec![9, 9],
        vec![8, 8],
        vec![17, 9],
        vec![12, 10],
        vec![9, 9, 9],
        vec![8, 12, 10],
        vec![5, 9, 17],
        vec![7, 7, 7],
    ]
}

fn rand_tensor(shape: &[usize], seed: u64) -> Tensor<f64> {
    let mut rng = Rng::new(seed);
    Tensor::from_fn(shape, |_| rng.uniform_in(-1.0, 1.0))
}

#[test]
fn all_flag_combos_agree_on_all_shapes() {
    for (si, shape) in shapes().iter().enumerate() {
        let u = rand_tensor(shape, 1000 + si as u64);
        let h = Hierarchy::new(shape, None).unwrap();
        let scale = value_range(u.data()).max(1.0);
        let reference = Decomposer::new(h.clone(), OptFlags::baseline())
            .unwrap()
            .decompose(&u)
            .unwrap();
        for flags in all_flag_combos() {
            let dec = Decomposer::new(h.clone(), flags).unwrap().decompose(&u).unwrap();
            assert_eq!(
                dec.coeffs.len(),
                reference.coeffs.len(),
                "{shape:?} {flags:?}: level count"
            );
            let cerr = linf_error(dec.coarse.data(), reference.coarse.data());
            assert!(
                cerr < 1e-9 * scale,
                "{shape:?} {flags:?}: coarse differs by {cerr}"
            );
            for (l, (a, b)) in dec.coeffs.iter().zip(&reference.coeffs).enumerate() {
                let serr = linf_error(a, b);
                assert!(
                    serr < 1e-9 * scale,
                    "{shape:?} {flags:?}: stream {l} differs by {serr}"
                );
            }
        }
    }
}

#[test]
fn cross_engine_recompose_round_trips() {
    // decompose with engine A, recompose with engine B: every pairing must
    // reproduce the input
    let combos = [OptFlags::baseline(), OptFlags::dr_dlvc(), OptFlags::all()];
    for shape in [vec![17, 9], vec![10, 11, 12]] {
        let u = rand_tensor(&shape, 77);
        let h = Hierarchy::new(&shape, None).unwrap();
        let scale = value_range(u.data()).max(1.0);
        for fa in combos {
            let dec = Decomposer::new(h.clone(), fa).unwrap().decompose(&u).unwrap();
            for fb in combos {
                let back = Decomposer::new(h.clone(), fb).unwrap().recompose(&dec).unwrap();
                let err = linf_error(u.data(), back.data());
                assert!(
                    err < 1e-9 * scale,
                    "{shape:?} {fa:?} -> {fb:?}: round trip {err}"
                );
            }
        }
    }
}

#[test]
fn partial_decompositions_agree_between_engines() {
    let shape = [17, 17];
    let u = rand_tensor(&shape, 5);
    let h = Hierarchy::new(&shape, None).unwrap();
    let scale = value_range(u.data()).max(1.0);
    for stop in 0..=h.nlevels() {
        let a = Decomposer::new(h.clone(), OptFlags::baseline())
            .unwrap()
            .decompose_to(&u, stop)
            .unwrap();
        let b = Decomposer::new(h.clone(), OptFlags::all())
            .unwrap()
            .decompose_to(&u, stop)
            .unwrap();
        assert_eq!(a.start_level, b.start_level);
        assert!(
            linf_error(a.coarse.data(), b.coarse.data()) < 1e-9 * scale,
            "stop {stop}"
        );
        for (x, y) in a.coeffs.iter().zip(&b.coeffs) {
            assert!(linf_error(x, y) < 1e-9 * scale, "stop {stop}");
        }
    }
}

#[test]
fn f32_engines_agree_within_single_precision() {
    let shape = [12, 14, 9];
    let mut rng = Rng::new(42);
    let u = Tensor::<f32>::from_fn(&shape, |_| rng.uniform_in(-3.0, 3.0) as f32);
    let h = Hierarchy::new(&shape, None).unwrap();
    let a = Decomposer::new(h.clone(), OptFlags::baseline())
        .unwrap()
        .decompose(&u)
        .unwrap();
    let b = Decomposer::new(h, OptFlags::all()).unwrap().decompose(&u).unwrap();
    assert!(linf_error(a.coarse.data(), b.coarse.data()) < 1e-3);
    for (x, y) in a.coeffs.iter().zip(&b.coeffs) {
        assert!(linf_error(x, y) < 1e-3);
    }
}
