//! Engine-equivalence suite: `decompose::baseline` (the original §2 method)
//! and `decompose::contiguous` (the §5-optimized engine) implement the same
//! transform, so their decompositions must agree to FP rounding across
//! every `OptFlags` ablation combination and across odd/even/1-d/2-d/3-d
//! shapes — and their outputs must be interchangeable at recompose time.
//!
//! The second half is the fused-vs-staged differential suite: the fused
//! decompose→quantize hot path (`OptFlags::fused`) must produce
//! **bit-identical compressed bytes and reconstructions** to the staged
//! path across every flag combination, 1/2/3-D dyadic and non-dyadic
//! shapes (incl. 17×33×65), f32 and f64, and the chunked and streamed
//! container paths — the staged path is the oracle.

use mgardp::chunk::{ChunkedConfig, Tiling};
use mgardp::compressors::{Compressor, MgardPlus, MgardPlusConfig, Tolerance};
use mgardp::data::rng::Rng;
use mgardp::decompose::{Decomposer, OptFlags};
use mgardp::grid::Hierarchy;
use mgardp::metrics::{linf_error, value_range};
use mgardp::stream::{compress_to_writer, InCoreSource, StreamConfig};
use mgardp::tensor::Tensor;

/// Every legal flag combination, baseline first (the Fig. 6 series plus the
/// non-cumulative DR+IVER variant).
fn all_flag_combos() -> Vec<OptFlags> {
    let mut combos = vec![
        OptFlags::baseline(),
        OptFlags::dr(),
        OptFlags::dr_dlvc(),
        OptFlags::dr_dlvc_bcc(),
        OptFlags::all(),
    ];
    combos.push(OptFlags {
        reorder: true,
        direct_load: false,
        batched: false,
        reuse: true,
        fused: false,
    });
    combos.push(OptFlags {
        reorder: true,
        direct_load: true,
        batched: false,
        reuse: true,
        fused: false,
    });
    combos
}

/// Shapes covering 1-d/2-d/3-d, odd and even extents, dyadic and non-dyadic.
fn shapes() -> Vec<Vec<usize>> {
    vec![
        vec![17],
        vec![16],
        vec![33],
        vec![9, 9],
        vec![8, 8],
        vec![17, 9],
        vec![12, 10],
        vec![9, 9, 9],
        vec![8, 12, 10],
        vec![5, 9, 17],
        vec![7, 7, 7],
    ]
}

fn rand_tensor(shape: &[usize], seed: u64) -> Tensor<f64> {
    let mut rng = Rng::new(seed);
    Tensor::from_fn(shape, |_| rng.uniform_in(-1.0, 1.0))
}

#[test]
fn all_flag_combos_agree_on_all_shapes() {
    for (si, shape) in shapes().iter().enumerate() {
        let u = rand_tensor(shape, 1000 + si as u64);
        let h = Hierarchy::new(shape, None).unwrap();
        let scale = value_range(u.data()).max(1.0);
        let reference = Decomposer::new(h.clone(), OptFlags::baseline())
            .unwrap()
            .decompose(&u)
            .unwrap();
        for flags in all_flag_combos() {
            let dec = Decomposer::new(h.clone(), flags).unwrap().decompose(&u).unwrap();
            assert_eq!(
                dec.coeffs.len(),
                reference.coeffs.len(),
                "{shape:?} {flags:?}: level count"
            );
            let cerr = linf_error(dec.coarse.data(), reference.coarse.data());
            assert!(
                cerr < 1e-9 * scale,
                "{shape:?} {flags:?}: coarse differs by {cerr}"
            );
            for (l, (a, b)) in dec.coeffs.iter().zip(&reference.coeffs).enumerate() {
                let serr = linf_error(a, b);
                assert!(
                    serr < 1e-9 * scale,
                    "{shape:?} {flags:?}: stream {l} differs by {serr}"
                );
            }
        }
    }
}

#[test]
fn cross_engine_recompose_round_trips() {
    // decompose with engine A, recompose with engine B: every pairing must
    // reproduce the input
    let combos = [OptFlags::baseline(), OptFlags::dr_dlvc(), OptFlags::all()];
    for shape in [vec![17, 9], vec![10, 11, 12]] {
        let u = rand_tensor(&shape, 77);
        let h = Hierarchy::new(&shape, None).unwrap();
        let scale = value_range(u.data()).max(1.0);
        for fa in combos {
            let dec = Decomposer::new(h.clone(), fa).unwrap().decompose(&u).unwrap();
            for fb in combos {
                let back = Decomposer::new(h.clone(), fb).unwrap().recompose(&dec).unwrap();
                let err = linf_error(u.data(), back.data());
                assert!(
                    err < 1e-9 * scale,
                    "{shape:?} {fa:?} -> {fb:?}: round trip {err}"
                );
            }
        }
    }
}

#[test]
fn partial_decompositions_agree_between_engines() {
    let shape = [17, 17];
    let u = rand_tensor(&shape, 5);
    let h = Hierarchy::new(&shape, None).unwrap();
    let scale = value_range(u.data()).max(1.0);
    for stop in 0..=h.nlevels() {
        let a = Decomposer::new(h.clone(), OptFlags::baseline())
            .unwrap()
            .decompose_to(&u, stop)
            .unwrap();
        let b = Decomposer::new(h.clone(), OptFlags::all())
            .unwrap()
            .decompose_to(&u, stop)
            .unwrap();
        assert_eq!(a.start_level, b.start_level);
        assert!(
            linf_error(a.coarse.data(), b.coarse.data()) < 1e-9 * scale,
            "stop {stop}"
        );
        for (x, y) in a.coeffs.iter().zip(&b.coeffs) {
            assert!(linf_error(x, y) < 1e-9 * scale, "stop {stop}");
        }
    }
}

// ---------------------------------------------------------------------------
// Fused-vs-staged differential suite
// ---------------------------------------------------------------------------

/// MGARD+ config with the given engine flags and (levelwise, adaptive)
/// ablation switches.
fn cfg(flags: OptFlags, levelwise: bool, adaptive: bool) -> MgardPlusConfig {
    MgardPlusConfig {
        levelwise,
        adaptive,
        flags,
        ..MgardPlusConfig::default()
    }
}

/// Compress `t` with the staged and the fused variant of `flags` and
/// assert byte identity of containers and bit identity of reconstructions.
fn assert_fused_matches_staged<T: mgardp::tensor::Scalar>(
    t: &Tensor<T>,
    flags: OptFlags,
    levelwise: bool,
    adaptive: bool,
    tau: f64,
    what: &str,
) {
    let staged = MgardPlus::new(cfg(OptFlags { fused: false, ..flags }, levelwise, adaptive));
    let fused = MgardPlus::new(cfg(OptFlags { fused: true, ..flags }, levelwise, adaptive));
    let b_staged = staged.compress(t, Tolerance::Abs(tau)).unwrap();
    let b_fused = fused.compress(t, Tolerance::Abs(tau)).unwrap();
    assert_eq!(b_staged, b_fused, "{what}: container bytes differ");
    let r_staged: Tensor<T> = staged.decompress(&b_staged).unwrap();
    let r_fused: Tensor<T> = fused.decompress(&b_fused).unwrap();
    assert_eq!(r_staged.shape(), t.shape(), "{what}: shape");
    for (a, b) in r_staged.data().iter().zip(r_fused.data()) {
        let (mut xa, mut xb) = (Vec::new(), Vec::new());
        a.write_le(&mut xa);
        b.write_le(&mut xb);
        assert_eq!(xa, xb, "{what}: reconstructions not bit-identical");
    }
    assert!(
        linf_error(t.data(), r_fused.data()) <= tau * (1.0 + 1e-9),
        "{what}: fused path broke the error bound"
    );
}

/// Shapes of the differential suite: 1/2/3-D, dyadic and non-dyadic.
fn diff_shapes() -> Vec<Vec<usize>> {
    vec![
        vec![33],
        vec![16],
        vec![17, 9],
        vec![12, 10],
        vec![9, 9, 9],
        vec![6, 10, 11],
    ]
}

#[test]
fn fused_bytes_match_staged_across_flags_and_shapes() {
    for shape in diff_shapes() {
        let u = rand_tensor(&shape, 4000 + shape.iter().sum::<usize>() as u64);
        for flags in [
            OptFlags::dr(),
            OptFlags::dr_dlvc(),
            OptFlags::dr_dlvc_bcc(),
            OptFlags::all_staged(),
        ] {
            for levelwise in [true, false] {
                assert_fused_matches_staged(
                    &u,
                    flags,
                    levelwise,
                    false,
                    1e-3,
                    &format!("{shape:?} {flags:?} levelwise={levelwise}"),
                );
            }
        }
    }
}

#[test]
fn fused_flag_is_inert_under_adaptive_termination() {
    // with adaptive termination the tier schedule is dynamic, so the fused
    // flag must fall back to the staged path — bytes identical by
    // construction, pinned here so the fallback never silently diverges
    for shape in [vec![33usize], vec![17, 9], vec![9, 9, 9]] {
        let u = rand_tensor(&shape, 4400 + shape.len() as u64);
        assert_fused_matches_staged(
            &u,
            OptFlags::all_staged(),
            true,
            true,
            1e-3,
            &format!("{shape:?} adaptive"),
        );
    }
}

#[test]
fn fused_matches_staged_17x33x65_f32_f64() {
    let shape = [17usize, 33, 65];
    let t32 = mgardp::data::synth::smooth_test_field(&shape);
    assert_fused_matches_staged(&t32, OptFlags::all_staged(), true, false, 1e-3, "f32");
    let t64 = Tensor::<f64>::from_fn(&shape, |ix| t32.at(ix) as f64);
    assert_fused_matches_staged(&t64, OptFlags::all_staged(), true, false, 1e-6, "f64");
}

#[test]
fn fused_matches_staged_chunked_and_streamed() {
    let t = mgardp::data::synth::smooth_test_field(&[17, 33, 65]);
    let tau = 1e-3;
    let chunk_cfg = ChunkedConfig {
        block_shape: vec![16],
        threads: 2,
        tiling: Tiling::Fixed,
    };
    let staged = MgardPlus::new(cfg(OptFlags::all_staged(), true, false));
    let fused = MgardPlus::new(cfg(OptFlags::all(), true, false));
    let b_staged = staged
        .clone()
        .chunked(chunk_cfg.clone())
        .compress(&t, Tolerance::Abs(tau))
        .unwrap();
    let b_fused = fused
        .clone()
        .chunked(chunk_cfg.clone())
        .compress(&t, Tolerance::Abs(tau))
        .unwrap();
    assert_eq!(b_staged, b_fused, "chunked containers differ");

    // the streaming path must agree with both
    let mut b_streamed = Vec::new();
    let scfg = StreamConfig {
        chunk: chunk_cfg,
        memory_budget: 64 * 1024,
        spool_dir: None,
    };
    compress_to_writer(
        &fused,
        &InCoreSource::new(&t),
        Tolerance::Abs(tau),
        &scfg,
        &mut b_streamed,
    )
    .unwrap();
    assert_eq!(b_streamed, b_staged, "streamed container differs");

    let back: Tensor<f32> = staged
        .chunked(ChunkedConfig {
            block_shape: vec![16],
            threads: 2,
            tiling: Tiling::Fixed,
        })
        .decompress(&b_fused)
        .unwrap();
    assert!(linf_error(t.data(), back.data()) <= tau);
}

#[test]
fn f32_engines_agree_within_single_precision() {
    let shape = [12, 14, 9];
    let mut rng = Rng::new(42);
    let u = Tensor::<f32>::from_fn(&shape, |_| rng.uniform_in(-3.0, 3.0) as f32);
    let h = Hierarchy::new(&shape, None).unwrap();
    let a = Decomposer::new(h.clone(), OptFlags::baseline())
        .unwrap()
        .decompose(&u)
        .unwrap();
    let b = Decomposer::new(h, OptFlags::all()).unwrap().decompose(&u).unwrap();
    assert!(linf_error(a.coarse.data(), b.coarse.data()) < 1e-3);
    for (x, y) in a.coeffs.iter().zip(&b.coeffs) {
        assert!(linf_error(x, y) < 1e-3);
    }
}
