//! Tier-1 contract of the storage seam and the serving daemon:
//!
//! * the filesystem, in-memory and mock-latency backends hold
//!   **byte-identical** objects for the same refactored field, and every
//!   retrieval path (planner, streaming decompressor) is
//!   backend-agnostic;
//! * the shared component cache is a real byte-capacity LRU — eviction
//!   order, restamping on hit, oversize bypass — and stays coherent when
//!   many threads fetch through it at once;
//! * `N` concurrent clients at distinct tolerances each get their
//!   certified `‖u − ũ‖_∞ ≤ τ` bound from one daemon, with and without
//!   simulated remote latency and injected transient failures.

use mgardp::chunk::{ChunkedCompressor, ChunkedConfig};
use mgardp::compressors::{Compressor, MgardPlus, Tolerance};
use mgardp::coordinator::refactor::RefactorStore;
use mgardp::data::synth;
use mgardp::metrics::linf_error;
use mgardp::serve::{RemoteField, ServeClient, ServeConfig, Server};
use mgardp::storage::{
    ComponentCache, FileStorage, MemoryStorage, MockStorage, Storage, StorageObject,
};
use mgardp::stream::StreamingDecompressor;
use mgardp::tensor::Tensor;
use std::sync::Arc;
use std::time::Duration;

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("mgardp_storage_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A fast mock: zero latency, no injected failures — pure pass-through
/// accounting, so differential checks stay cheap.
fn passthrough_mock(inner: Arc<dyn Storage>) -> Arc<MockStorage> {
    Arc::new(MockStorage::new(inner, Duration::ZERO, 0))
}

#[test]
fn backends_hold_byte_identical_objects() {
    let t = synth::smooth_test_field(&[19, 17]);
    let dir = temp_dir("diff");
    let file_store = RefactorStore::create(&dir).unwrap();
    let mem: Arc<dyn Storage> = Arc::new(MemoryStorage::new());
    let mem_store = RefactorStore::with_storage(Arc::clone(&mem));
    file_store.write_field_progressive("u", &t, None, 3).unwrap();
    mem_store.write_field_progressive("u", &t, None, 3).unwrap();

    let file_backend: Arc<dyn Storage> = Arc::new(FileStorage::open(&dir).unwrap());
    let mock_backend: Arc<dyn Storage> = passthrough_mock(Arc::clone(&mem));

    // identical key sets, identical bytes, on every backend
    let keys = file_backend.list("").unwrap();
    assert_eq!(keys, mem.list("").unwrap());
    assert_eq!(keys, mock_backend.list("").unwrap());
    assert!(keys.contains(&"u/manifest.bin".to_string()), "{keys:?}");
    assert!(keys.contains(&"u/components.bin".to_string()));
    for key in &keys {
        let reference = file_backend.read(key).unwrap();
        assert_eq!(reference, mem.read(key).unwrap(), "{key} differs in memory");
        assert_eq!(
            reference,
            mock_backend.read(key).unwrap(),
            "{key} differs through the mock"
        );
        // ranged reads agree with whole-object reads
        let n = file_backend.size(key).unwrap();
        assert_eq!(n as usize, reference.len());
        let mid = n / 2;
        assert_eq!(
            file_backend.read_range(key, mid, n - mid).unwrap(),
            mem.read_range(key, mid, n - mid).unwrap(),
            "{key} tail range differs"
        );
    }

    // retrieval is backend-agnostic: same certificate, same reconstruction
    let tau = 0.02;
    let (from_file, plan_file) = file_store.progressive("u").unwrap().retrieve::<f32>(tau).unwrap();
    let (from_mem, plan_mem) = mem_store.progressive("u").unwrap().retrieve::<f32>(tau).unwrap();
    let mock_store = RefactorStore::with_storage(passthrough_mock(Arc::clone(&mem)));
    let (from_mock, plan_mock) = mock_store.progressive("u").unwrap().retrieve::<f32>(tau).unwrap();
    assert_eq!(plan_file.certified_bound, plan_mem.certified_bound);
    assert_eq!(plan_file.certified_bound, plan_mock.certified_bound);
    assert_eq!(from_file.data(), from_mem.data());
    assert_eq!(from_file.data(), from_mock.data());
    assert!(linf_error(t.data(), from_file.data()) <= tau);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn streaming_decompressor_runs_over_any_backend() {
    let t = synth::smooth_test_field(&[20, 21, 11]);
    let comp = ChunkedCompressor::new(
        MgardPlus::default(),
        ChunkedConfig {
            block_shape: vec![8, 8, 8],
            threads: 2,
            ..ChunkedConfig::default()
        },
    );
    let bytes = comp.compress(&t, Tolerance::Abs(1e-3)).unwrap();

    let mem: Arc<dyn Storage> = Arc::new(MemoryStorage::new());
    mem.write("fields/u.mgrp", &bytes).unwrap();
    let dir = temp_dir("streamobj");
    let file: Arc<dyn Storage> = Arc::new(FileStorage::create(&dir).unwrap());
    file.write("fields/u.mgrp", &bytes).unwrap();

    let reference: Tensor<f32> = StreamingDecompressor::open(std::io::Cursor::new(&bytes))
        .unwrap()
        .decompress()
        .unwrap();
    for (name, backend) in [
        ("memory", Arc::clone(&mem)),
        ("file", Arc::clone(&file)),
        ("mock", passthrough_mock(Arc::clone(&mem)) as Arc<dyn Storage>),
    ] {
        let mut d = StreamingDecompressor::open_storage(Arc::clone(&backend), "fields/u.mgrp")
            .unwrap();
        let full: Tensor<f32> = d.decompress().unwrap();
        assert_eq!(reference.data(), full.data(), "{name} full decode differs");
        let region: Tensor<f32> = d.decompress_region(&[3, 5, 2], &[9, 9, 7]).unwrap();
        let direct = reference.block(&[3, 5, 2], &[9, 9, 7]).unwrap();
        assert_eq!(direct.data(), region.data(), "{name} region decode differs");
        assert!(linf_error(t.data(), full.data()) <= 1e-3 * (1.0 + 1e-6));
    }

    // the adapter is a faithful Read + Seek view of the object
    let mut obj = StorageObject::open(Arc::clone(&mem), "fields/u.mgrp").unwrap();
    assert_eq!(obj.size() as usize, bytes.len());
    let mut round = Vec::new();
    std::io::Read::read_to_end(&mut obj, &mut round).unwrap();
    assert_eq!(round, bytes);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn component_cache_is_a_byte_capacity_lru() {
    let payload = |n: usize| Arc::new(vec![0u8; n]);
    let cache = ComponentCache::new(100);
    cache.insert("a", payload(40));
    cache.insert("b", payload(40));
    assert!(cache.get("a").is_some()); // restamp: a is now most recent
    cache.insert("c", payload(40)); // over capacity -> evict LRU = b
    assert!(cache.get("b").is_none(), "b should have been evicted");
    assert!(cache.get("a").is_some());
    assert!(cache.get("c").is_some());
    let s = cache.stats();
    assert_eq!(s.evictions, 1);
    assert_eq!(s.entries, 2);
    assert_eq!(s.bytes_used, 80);
    assert!(s.bytes_used <= s.capacity);

    // an oversize payload bypasses the cache instead of flushing it
    cache.insert("huge", payload(1000));
    assert!(cache.get("huge").is_none());
    assert!(cache.get("a").is_some());
    assert!(cache.get("c").is_some());

    // recency order is observable: most recently used last
    assert_eq!(cache.keys_by_recency(), vec!["a", "c"]);
}

#[test]
fn shared_cache_is_coherent_under_contention() {
    // 8 threads × 50 get_or_fetch over 10 keys through a cache that can
    // hold only 4 payloads: every fetch must return the right payload,
    // and the accounting must stay exact
    let cache = Arc::new(ComponentCache::new(4 * 64));
    let mut handles = Vec::new();
    for thread in 0..8u64 {
        let cache = Arc::clone(&cache);
        handles.push(std::thread::spawn(move || {
            for i in 0..50u64 {
                let k = (thread + i) % 10;
                let key = format!("comp/{k}");
                let got = cache
                    .get_or_fetch(&key, || Ok(vec![k as u8; 64]))
                    .unwrap();
                assert_eq!(got.len(), 64);
                assert!(got.iter().all(|&b| b == k as u8), "wrong payload for {key}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let s = cache.stats();
    assert_eq!(s.hits + s.misses, 8 * 50);
    assert!(s.misses >= 10, "every key misses at least once");
    assert!(s.bytes_used <= s.capacity);
    assert!(s.entries <= 4);
}

/// The acceptance scenario: one daemon, ≥ 4 concurrent clients at
/// distinct tolerances, every certificate satisfied.
fn concurrent_clients_against(field_store: RefactorStore, t: &Tensor<f32>, cfg: &ServeConfig) {
    let field = field_store.progressive("u").unwrap();
    let mut server = Server::start(field, cfg).unwrap();
    let addr = server.addr();
    let taus = [0.25, 0.05, 0.01, 0.002];
    let mut handles = Vec::new();
    for &tau in &taus {
        let reference = t.clone();
        handles.push(std::thread::spawn(move || {
            let mut remote: RemoteField<f32> = RemoteField::open(addr).unwrap();
            let (back, plan) = remote.refine(tau).unwrap();
            assert!(
                plan.certified_bound <= tau,
                "τ {tau}: certificate {}",
                plan.certified_bound
            );
            let err = linf_error(reference.data(), back.data());
            assert!(err <= tau, "τ {tau}: L∞ {err} exceeds the bound");
            // tightening on the same connection transfers only a delta
            let before = remote.bytes_fetched();
            let (tight, plan2) = remote.refine(tau / 2.0).unwrap();
            assert!(plan2.certified_bound <= tau / 2.0);
            assert!(linf_error(reference.data(), tight.data()) <= tau / 2.0);
            assert!(remote.bytes_fetched() >= before);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let stats = server.stats();
    assert!(stats.connections >= taus.len() as u64, "{stats:?}");
    assert!(
        stats.hits > 0,
        "concurrent clients over one cache must share fetches: {stats:?}"
    );
    server.stop();
}

#[test]
fn four_concurrent_clients_distinct_tolerances() {
    let t = synth::smooth_test_field(&[23, 19]);
    let store = RefactorStore::with_storage(Arc::new(MemoryStorage::new()));
    store.write_field_progressive("u", &t, None, 3).unwrap();
    concurrent_clients_against(store, &t, &ServeConfig::default());
}

#[test]
fn four_concurrent_clients_with_latency_and_failures() {
    let t = synth::smooth_test_field(&[23, 19]);
    let mem = Arc::new(MemoryStorage::new());
    let writer = RefactorStore::with_storage(Arc::clone(&mem) as Arc<dyn Storage>);
    writer.write_field_progressive("u", &t, None, 3).unwrap();
    let mock = Arc::new(MockStorage::new(
        Arc::clone(&mem) as Arc<dyn Storage>,
        Duration::from_micros(100),
        7, // every 7th read op fails transiently
    ));
    let store = RefactorStore::with_storage(Arc::clone(&mock) as Arc<dyn Storage>);
    let cfg = ServeConfig {
        retries: 6,
        ..ServeConfig::default()
    };
    concurrent_clients_against(store, &t, &cfg);
    assert!(mock.injected_failures() > 0, "the fault injector never fired");
}

/// The production soak: 16 clients hammer one daemon through a
/// latency-and-fault-injecting backend, in three phases.
///
/// * **Stampede** — a barrier releases every client into the same cold
///   retrieve at once: the cache's misses (== backend fetches issued)
///   must grow by *exactly* the plan's component count — concurrent
///   misses on one component issue exactly one backend fetch — and at
///   least one waiter must have coalesced onto another's flight.
/// * **Mixed rounds** — each client runs randomized
///   manifest/plan/fetch/retrieve/stats rounds at its own randomized τ;
///   every retrieve must satisfy `‖u − ũ‖∞ ≤ τ` *and* be byte-identical
///   to a sequential oracle over the bare backend.
/// * **Refinement** — each client reconnects as a [`RemoteField`] and
///   tightens τ monotonically: bytes fetched never decrease, and
///   re-asking for a looser τ transfers zero new bytes (the
///   per-connection fetch floor never regresses).
///
/// Afterwards the daemon must be clean: no deadline expiries, no
/// refusals, an empty accept queue, and `stop()` returning proves every
/// worker drained.
#[test]
fn sixteen_client_soak_with_faults_and_latency() {
    use mgardp::data::rng::Rng;
    use std::sync::Barrier;

    const CLIENTS: usize = 16;
    const TAU_STAMPEDE: f64 = 0.01;

    let t = synth::smooth_test_field(&[23, 19]);
    let mem = Arc::new(MemoryStorage::new());
    let writer = RefactorStore::with_storage(Arc::clone(&mem) as Arc<dyn Storage>);
    writer.write_field_progressive("u", &t, None, 3).unwrap();
    let mock = Arc::new(MockStorage::new(
        Arc::clone(&mem) as Arc<dyn Storage>,
        Duration::from_millis(1),
        9, // every 9th read op fails transiently
    ));
    let store = RefactorStore::with_storage(Arc::clone(&mock) as Arc<dyn Storage>);
    let cfg = ServeConfig {
        max_connections: 20, // 16 soak clients + the harness's own probes
        queue_depth: 16,
        retries: 8,
        request_timeout_ms: 10_000,
        ..ServeConfig::default()
    };
    let mut server = Server::start(store.progressive("u").unwrap(), &cfg).unwrap();
    let addr = server.addr();

    // fresh connection -> zero floor -> the full plan the stampede fetches
    let (baseline, stampede_components) = {
        let mut probe = ServeClient::connect(addr).unwrap();
        let plan = probe.plan(TAU_STAMPEDE, None).unwrap();
        (probe.stats().unwrap(), plan.components().len())
    };
    assert!(stampede_components >= 2, "stampede needs a multi-component plan");

    let start = Arc::new(Barrier::new(CLIENTS + 1));
    let stampeded = Arc::new(Barrier::new(CLIENTS + 1));
    let measured = Arc::new(Barrier::new(CLIENTS + 1));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|client_id| {
            let reference = t.clone();
            let mem = Arc::clone(&mem) as Arc<dyn Storage>;
            let start = Arc::clone(&start);
            let stampeded = Arc::clone(&stampeded);
            let measured = Arc::clone(&measured);
            std::thread::spawn(move || {
                let oracle = RefactorStore::with_storage(mem).progressive("u").unwrap();
                let mut rng = Rng::new(0x50AC + client_id as u64);
                let mut client = ServeClient::connect(addr).unwrap();

                // phase 1: barrier-released identical cold retrieve
                start.wait();
                let (back, bound) = client.retrieve::<f32>(TAU_STAMPEDE, None).unwrap();
                assert!(bound <= TAU_STAMPEDE, "client {client_id}: bound {bound}");
                let err = linf_error(reference.data(), back.data());
                assert!(err <= TAU_STAMPEDE, "client {client_id}: L∞ {err}");
                let (expect, _) = oracle.retrieve::<f32>(TAU_STAMPEDE).unwrap();
                assert_eq!(
                    back.data(),
                    expect.data(),
                    "client {client_id}: stampede result diverged from the oracle"
                );
                stampeded.wait();
                measured.wait(); // let the harness read the stampede stats

                // phase 2: mixed rounds at randomized tolerances
                for round in 0..3 {
                    let tau = 10f64.powf(rng.uniform_in(-2.4, -0.5));
                    let manifest = client.manifest().unwrap();
                    assert_eq!(manifest.shape, vec![23, 19]);
                    let plan = client
                        .plan(tau, None)
                        .unwrap_or_else(|e| panic!("client {client_id} round {round}: {e}"));
                    assert!(plan.certified_bound <= tau);
                    if let Some(&id) = plan.components().first() {
                        client.fetch(id).unwrap();
                    }
                    let (back, bound) = client.retrieve::<f32>(tau, None).unwrap();
                    assert!(bound <= tau, "client {client_id} round {round}");
                    let err = linf_error(reference.data(), back.data());
                    assert!(err <= tau, "client {client_id} round {round}: L∞ {err} > τ {tau}");
                    let (expect, oracle_plan) = oracle.retrieve::<f32>(tau).unwrap();
                    assert_eq!(bound, oracle_plan.certified_bound, "client {client_id}");
                    assert_eq!(
                        back.data(),
                        expect.data(),
                        "client {client_id} round {round}: τ {tau} diverged from the oracle"
                    );
                    client.stats().unwrap();
                }
                drop(client);

                // phase 3: monotone refinement on a fresh connection
                let mut remote: RemoteField<f32> = RemoteField::open(addr).unwrap();
                let mut fetched_floor = 0;
                for tau in [0.3, 0.05, 0.01] {
                    let (back, plan) = remote.refine(tau).unwrap();
                    assert!(plan.certified_bound <= tau);
                    let err = linf_error(reference.data(), back.data());
                    assert!(err <= tau, "client {client_id}: refine L∞ {err} > τ {tau}");
                    assert!(
                        remote.bytes_fetched() >= fetched_floor,
                        "client {client_id}: fetch floor regressed"
                    );
                    fetched_floor = remote.bytes_fetched();
                }
                // loosening back transfers nothing: the floor is monotone
                let (_, relax) = remote.refine(0.3).unwrap();
                assert!(relax.certified_bound <= 0.3);
                assert_eq!(
                    remote.bytes_fetched(),
                    fetched_floor,
                    "client {client_id}: a looser τ re-fetched data"
                );
            })
        })
        .collect();

    // exactly one backend fetch per component, no matter how many
    // concurrent misses: misses == fetches issued by construction
    start.wait();
    stampeded.wait();
    {
        let mut probe = ServeClient::connect(addr).unwrap();
        let after = probe.stats().unwrap();
        assert_eq!(
            after.misses - baseline.misses,
            stampede_components as u64,
            "stampede issued duplicate backend fetches: {after:?}"
        );
        assert!(
            after.coalesced > baseline.coalesced,
            "no client ever coalesced onto another's fetch: {after:?}"
        );
    }
    measured.wait();

    for h in handles {
        h.join().unwrap();
    }
    let stats = server.stats();
    assert_eq!(stats.deadline_expired, 0, "deadline leak: {stats:?}");
    assert_eq!(stats.refused, 0, "admission refused a soak client: {stats:?}");
    assert_eq!(stats.queued, 0, "accept queue did not drain: {stats:?}");
    assert!(stats.connections >= 2 * CLIENTS as u64 + 2, "{stats:?}");
    assert!(mock.injected_failures() > 0, "the fault injector never fired");
    server.stop(); // returning at all proves every worker drained
}

#[test]
fn stats_and_shutdown_over_the_wire() {
    let t = synth::smooth_test_field(&[15, 14]);
    let store = RefactorStore::with_storage(Arc::new(MemoryStorage::new()));
    store.write_field_progressive("u", &t, None, 3).unwrap();
    let field = store.progressive("u").unwrap();
    let mut server = Server::start(field, &ServeConfig::default()).unwrap();
    let mut client = ServeClient::connect(server.addr()).unwrap();
    let (back, bound) = client.retrieve::<f32>(0.05, None).unwrap();
    assert!(bound <= 0.05);
    assert!(linf_error(t.data(), back.data()) <= 0.05);
    let stats = client.stats().unwrap();
    assert!(stats.requests >= 2);
    assert_eq!(stats.capacity, ServeConfig::default().cache_bytes);
    client.shutdown().unwrap();
    server.stop(); // must join promptly after the protocol shutdown
}
