//! Tier-1 contract of the storage seam and the serving daemon:
//!
//! * the filesystem, in-memory and mock-latency backends hold
//!   **byte-identical** objects for the same refactored field, and every
//!   retrieval path (planner, streaming decompressor) is
//!   backend-agnostic;
//! * the shared component cache is a real byte-capacity LRU — eviction
//!   order, restamping on hit, oversize bypass — and stays coherent when
//!   many threads fetch through it at once;
//! * `N` concurrent clients at distinct tolerances each get their
//!   certified `‖u − ũ‖_∞ ≤ τ` bound from one daemon, with and without
//!   simulated remote latency and injected transient failures.

use mgardp::chunk::{ChunkedCompressor, ChunkedConfig};
use mgardp::compressors::{Compressor, MgardPlus, Tolerance};
use mgardp::coordinator::refactor::RefactorStore;
use mgardp::data::synth;
use mgardp::metrics::linf_error;
use mgardp::serve::{RemoteField, ServeClient, ServeConfig, Server};
use mgardp::storage::{
    ComponentCache, FileStorage, MemoryStorage, MockStorage, Storage, StorageObject,
};
use mgardp::stream::StreamingDecompressor;
use mgardp::tensor::Tensor;
use std::sync::Arc;
use std::time::Duration;

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("mgardp_storage_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A fast mock: zero latency, no injected failures — pure pass-through
/// accounting, so differential checks stay cheap.
fn passthrough_mock(inner: Arc<dyn Storage>) -> Arc<MockStorage> {
    Arc::new(MockStorage::new(inner, Duration::ZERO, 0))
}

#[test]
fn backends_hold_byte_identical_objects() {
    let t = synth::smooth_test_field(&[19, 17]);
    let dir = temp_dir("diff");
    let file_store = RefactorStore::create(&dir).unwrap();
    let mem: Arc<dyn Storage> = Arc::new(MemoryStorage::new());
    let mem_store = RefactorStore::with_storage(Arc::clone(&mem));
    file_store.write_field_progressive("u", &t, None, 3).unwrap();
    mem_store.write_field_progressive("u", &t, None, 3).unwrap();

    let file_backend: Arc<dyn Storage> = Arc::new(FileStorage::open(&dir).unwrap());
    let mock_backend: Arc<dyn Storage> = passthrough_mock(Arc::clone(&mem));

    // identical key sets, identical bytes, on every backend
    let keys = file_backend.list("").unwrap();
    assert_eq!(keys, mem.list("").unwrap());
    assert_eq!(keys, mock_backend.list("").unwrap());
    assert!(keys.contains(&"u/manifest.bin".to_string()), "{keys:?}");
    assert!(keys.contains(&"u/components.bin".to_string()));
    for key in &keys {
        let reference = file_backend.read(key).unwrap();
        assert_eq!(reference, mem.read(key).unwrap(), "{key} differs in memory");
        assert_eq!(
            reference,
            mock_backend.read(key).unwrap(),
            "{key} differs through the mock"
        );
        // ranged reads agree with whole-object reads
        let n = file_backend.size(key).unwrap();
        assert_eq!(n as usize, reference.len());
        let mid = n / 2;
        assert_eq!(
            file_backend.read_range(key, mid, n - mid).unwrap(),
            mem.read_range(key, mid, n - mid).unwrap(),
            "{key} tail range differs"
        );
    }

    // retrieval is backend-agnostic: same certificate, same reconstruction
    let tau = 0.02;
    let (from_file, plan_file) = file_store.progressive("u").unwrap().retrieve::<f32>(tau).unwrap();
    let (from_mem, plan_mem) = mem_store.progressive("u").unwrap().retrieve::<f32>(tau).unwrap();
    let mock_store = RefactorStore::with_storage(passthrough_mock(Arc::clone(&mem)));
    let (from_mock, plan_mock) = mock_store.progressive("u").unwrap().retrieve::<f32>(tau).unwrap();
    assert_eq!(plan_file.certified_bound, plan_mem.certified_bound);
    assert_eq!(plan_file.certified_bound, plan_mock.certified_bound);
    assert_eq!(from_file.data(), from_mem.data());
    assert_eq!(from_file.data(), from_mock.data());
    assert!(linf_error(t.data(), from_file.data()) <= tau);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn streaming_decompressor_runs_over_any_backend() {
    let t = synth::smooth_test_field(&[20, 21, 11]);
    let comp = ChunkedCompressor::new(
        MgardPlus::default(),
        ChunkedConfig {
            block_shape: vec![8, 8, 8],
            threads: 2,
            ..ChunkedConfig::default()
        },
    );
    let bytes = comp.compress(&t, Tolerance::Abs(1e-3)).unwrap();

    let mem: Arc<dyn Storage> = Arc::new(MemoryStorage::new());
    mem.write("fields/u.mgrp", &bytes).unwrap();
    let dir = temp_dir("streamobj");
    let file: Arc<dyn Storage> = Arc::new(FileStorage::create(&dir).unwrap());
    file.write("fields/u.mgrp", &bytes).unwrap();

    let reference: Tensor<f32> = StreamingDecompressor::open(std::io::Cursor::new(&bytes))
        .unwrap()
        .decompress()
        .unwrap();
    for (name, backend) in [
        ("memory", Arc::clone(&mem)),
        ("file", Arc::clone(&file)),
        ("mock", passthrough_mock(Arc::clone(&mem)) as Arc<dyn Storage>),
    ] {
        let mut d = StreamingDecompressor::open_storage(Arc::clone(&backend), "fields/u.mgrp")
            .unwrap();
        let full: Tensor<f32> = d.decompress().unwrap();
        assert_eq!(reference.data(), full.data(), "{name} full decode differs");
        let region: Tensor<f32> = d.decompress_region(&[3, 5, 2], &[9, 9, 7]).unwrap();
        let direct = reference.block(&[3, 5, 2], &[9, 9, 7]).unwrap();
        assert_eq!(direct.data(), region.data(), "{name} region decode differs");
        assert!(linf_error(t.data(), full.data()) <= 1e-3 * (1.0 + 1e-6));
    }

    // the adapter is a faithful Read + Seek view of the object
    let mut obj = StorageObject::open(Arc::clone(&mem), "fields/u.mgrp").unwrap();
    assert_eq!(obj.size() as usize, bytes.len());
    let mut round = Vec::new();
    std::io::Read::read_to_end(&mut obj, &mut round).unwrap();
    assert_eq!(round, bytes);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn component_cache_is_a_byte_capacity_lru() {
    let payload = |n: usize| Arc::new(vec![0u8; n]);
    let cache = ComponentCache::new(100);
    cache.insert("a", payload(40));
    cache.insert("b", payload(40));
    assert!(cache.get("a").is_some()); // restamp: a is now most recent
    cache.insert("c", payload(40)); // over capacity -> evict LRU = b
    assert!(cache.get("b").is_none(), "b should have been evicted");
    assert!(cache.get("a").is_some());
    assert!(cache.get("c").is_some());
    let s = cache.stats();
    assert_eq!(s.evictions, 1);
    assert_eq!(s.entries, 2);
    assert_eq!(s.bytes_used, 80);
    assert!(s.bytes_used <= s.capacity);

    // an oversize payload bypasses the cache instead of flushing it
    cache.insert("huge", payload(1000));
    assert!(cache.get("huge").is_none());
    assert!(cache.get("a").is_some());
    assert!(cache.get("c").is_some());

    // recency order is observable: most recently used last
    assert_eq!(cache.keys_by_recency(), vec!["a", "c"]);
}

#[test]
fn shared_cache_is_coherent_under_contention() {
    // 8 threads × 50 get_or_fetch over 10 keys through a cache that can
    // hold only 4 payloads: every fetch must return the right payload,
    // and the accounting must stay exact
    let cache = Arc::new(ComponentCache::new(4 * 64));
    let mut handles = Vec::new();
    for thread in 0..8u64 {
        let cache = Arc::clone(&cache);
        handles.push(std::thread::spawn(move || {
            for i in 0..50u64 {
                let k = (thread + i) % 10;
                let key = format!("comp/{k}");
                let got = cache
                    .get_or_fetch(&key, || Ok(vec![k as u8; 64]))
                    .unwrap();
                assert_eq!(got.len(), 64);
                assert!(got.iter().all(|&b| b == k as u8), "wrong payload for {key}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let s = cache.stats();
    assert_eq!(s.hits + s.misses, 8 * 50);
    assert!(s.misses >= 10, "every key misses at least once");
    assert!(s.bytes_used <= s.capacity);
    assert!(s.entries <= 4);
}

/// The acceptance scenario: one daemon, ≥ 4 concurrent clients at
/// distinct tolerances, every certificate satisfied.
fn concurrent_clients_against(field_store: RefactorStore, t: &Tensor<f32>, cfg: &ServeConfig) {
    let field = field_store.progressive("u").unwrap();
    let mut server = Server::start(field, cfg).unwrap();
    let addr = server.addr();
    let taus = [0.25, 0.05, 0.01, 0.002];
    let mut handles = Vec::new();
    for &tau in &taus {
        let reference = t.clone();
        handles.push(std::thread::spawn(move || {
            let mut remote: RemoteField<f32> = RemoteField::open(addr).unwrap();
            let (back, plan) = remote.refine(tau).unwrap();
            assert!(
                plan.certified_bound <= tau,
                "τ {tau}: certificate {}",
                plan.certified_bound
            );
            let err = linf_error(reference.data(), back.data());
            assert!(err <= tau, "τ {tau}: L∞ {err} exceeds the bound");
            // tightening on the same connection transfers only a delta
            let before = remote.bytes_fetched();
            let (tight, plan2) = remote.refine(tau / 2.0).unwrap();
            assert!(plan2.certified_bound <= tau / 2.0);
            assert!(linf_error(reference.data(), tight.data()) <= tau / 2.0);
            assert!(remote.bytes_fetched() >= before);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let stats = server.stats();
    assert!(stats.connections >= taus.len() as u64, "{stats:?}");
    assert!(
        stats.hits > 0,
        "concurrent clients over one cache must share fetches: {stats:?}"
    );
    server.stop();
}

#[test]
fn four_concurrent_clients_distinct_tolerances() {
    let t = synth::smooth_test_field(&[23, 19]);
    let store = RefactorStore::with_storage(Arc::new(MemoryStorage::new()));
    store.write_field_progressive("u", &t, None, 3).unwrap();
    concurrent_clients_against(store, &t, &ServeConfig::default());
}

#[test]
fn four_concurrent_clients_with_latency_and_failures() {
    let t = synth::smooth_test_field(&[23, 19]);
    let mem = Arc::new(MemoryStorage::new());
    let writer = RefactorStore::with_storage(Arc::clone(&mem) as Arc<dyn Storage>);
    writer.write_field_progressive("u", &t, None, 3).unwrap();
    let mock = Arc::new(MockStorage::new(
        Arc::clone(&mem) as Arc<dyn Storage>,
        Duration::from_micros(100),
        7, // every 7th read op fails transiently
    ));
    let store = RefactorStore::with_storage(Arc::clone(&mock) as Arc<dyn Storage>);
    let cfg = ServeConfig {
        retries: 6,
        ..ServeConfig::default()
    };
    concurrent_clients_against(store, &t, &cfg);
    assert!(mock.injected_failures() > 0, "the fault injector never fired");
}

#[test]
fn stats_and_shutdown_over_the_wire() {
    let t = synth::smooth_test_field(&[15, 14]);
    let store = RefactorStore::with_storage(Arc::new(MemoryStorage::new()));
    store.write_field_progressive("u", &t, None, 3).unwrap();
    let field = store.progressive("u").unwrap();
    let mut server = Server::start(field, &ServeConfig::default()).unwrap();
    let mut client = ServeClient::connect(server.addr()).unwrap();
    let (back, bound) = client.retrieve::<f32>(0.05, None).unwrap();
    assert!(bound <= 0.05);
    assert!(linf_error(t.data(), back.data()) <= 0.05);
    let stats = client.stats().unwrap();
    assert!(stats.requests >= 2);
    assert_eq!(stats.capacity, ServeConfig::default().cache_bytes);
    client.shutdown().unwrap();
    server.stop(); // must join promptly after the protocol shutdown
}
