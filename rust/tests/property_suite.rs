//! Randomized property suite (in-house generator; no proptest in the
//! offline vendor set): every invariant the stack's correctness rests on,
//! exercised over randomly drawn shapes, dimensionalities, data
//! distributions and tolerances, plus failure injection on the container
//! formats.

use mgardp::compressors::{all_compressors, Compressor, Tolerance};
use mgardp::data::rng::Rng;
use mgardp::decompose::{Decomposer, OptFlags};
use mgardp::encode::{huffman_decode, huffman_encode};
use mgardp::grid::Hierarchy;
use mgardp::metrics::{linf_error, value_range};
use mgardp::tensor::Tensor;

/// Draw a random shape with 1..=4 dims, sizes 5..=28, total <= 60k points.
fn random_shape(rng: &mut Rng) -> Vec<usize> {
    loop {
        let d = 1 + rng.below(4);
        let shape: Vec<usize> = (0..d).map(|_| 5 + rng.below(24)).collect();
        if shape.iter().product::<usize>() <= 60_000 {
            return shape;
        }
    }
}

/// Draw random field data from one of several distributions.
fn random_field(shape: &[usize], rng: &mut Rng) -> Tensor<f64> {
    match rng.below(4) {
        // smooth separable waves
        0 => Tensor::from_fn(shape, |ix| {
            ix.iter()
                .enumerate()
                .map(|(k, &i)| ((i as f64) * 0.21 * (k + 1) as f64).sin())
                .sum()
        }),
        // white noise
        1 => Tensor::from_fn(shape, |_| rng.uniform_in(-1.0, 1.0)),
        // heavy-tailed magnitudes
        2 => Tensor::from_fn(shape, |_| {
            let sign = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
            sign * rng.uniform_in(0.0, 9.0).exp()
        }),
        // piecewise constant with jumps
        _ => Tensor::from_fn(shape, |ix| {
            if ix.iter().sum::<usize>() % 7 < 3 {
                4.0
            } else {
                -1.5
            }
        }),
    }
}

#[test]
fn decompose_recompose_identity_random_shapes() {
    let mut rng = Rng::new(0xD0C5);
    for trial in 0..25 {
        let shape = random_shape(&mut rng);
        let u = random_field(&shape, &mut rng);
        let h = Hierarchy::new(&shape, None).unwrap();
        let dec = Decomposer::new(h, OptFlags::all()).unwrap();
        let d = dec.decompose(&u).unwrap();
        let back = dec.recompose(&d).unwrap();
        let err = linf_error(u.data(), back.data());
        let scale = value_range(u.data()).max(1.0);
        assert!(
            err < 1e-9 * scale,
            "trial {trial} shape {shape:?}: round-trip err {err}"
        );
    }
}

#[test]
fn engines_agree_random_shapes() {
    let mut rng = Rng::new(0xE9E5);
    for trial in 0..10 {
        let shape = random_shape(&mut rng);
        let u = random_field(&shape, &mut rng);
        let h = Hierarchy::new(&shape, None).unwrap();
        let fast = Decomposer::new(h.clone(), OptFlags::all()).unwrap();
        let slow = Decomposer::new(h, OptFlags::baseline()).unwrap();
        let a = fast.decompose(&u).unwrap();
        let b = slow.decompose(&u).unwrap();
        let scale = value_range(u.data()).max(1.0);
        assert!(
            linf_error(a.coarse.data(), b.coarse.data()) < 1e-8 * scale,
            "trial {trial} {shape:?} coarse"
        );
        for (x, y) in a.coeffs.iter().zip(&b.coeffs) {
            assert!(linf_error(x, y) < 1e-8 * scale, "trial {trial} {shape:?}");
        }
    }
}

#[test]
fn partial_recompositions_are_consistent_random() {
    // recompose_to_level(full decomposition, l) == coarse of decompose_to(l)
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..8 {
        let shape = random_shape(&mut rng);
        let u = random_field(&shape, &mut rng);
        let h = Hierarchy::new(&shape, None).unwrap();
        let dec = Decomposer::new(h.clone(), OptFlags::all()).unwrap();
        let full = dec.decompose(&u).unwrap();
        let scale = value_range(u.data()).max(1.0);
        for l in 0..h.nlevels() {
            let a = dec.recompose_to_level(&full, l).unwrap();
            let b = dec.decompose_to(&u, l).unwrap();
            assert!(
                linf_error(a.data(), b.coarse.data()) < 1e-8 * scale,
                "{shape:?} level {l}"
            );
        }
    }
}

#[test]
fn error_bound_random_everything() {
    // random shape × random distribution × random tolerance × every codec
    let mut rng = Rng::new(0x70E1);
    for trial in 0..6 {
        let shape = random_shape(&mut rng);
        let u64field = random_field(&shape, &mut rng);
        let u = Tensor::<f32>::from_vec(
            &shape,
            u64field.data().iter().map(|&v| v as f32).collect(),
        )
        .unwrap();
        let rel = [1e-1, 1e-2, 1e-3][rng.below(3)];
        let range = value_range(u.data());
        let tau = rel * if range > 0.0 { range } else { 1.0 };
        for c in all_compressors::<f32>() {
            let bytes = c.compress(&u, Tolerance::Rel(rel)).unwrap();
            let back = c.decompress(&bytes).unwrap();
            let err = linf_error(u.data(), back.data());
            assert!(
                err <= tau * (1.0 + 1e-6),
                "trial {trial} {} {shape:?} rel {rel}: {err} > {tau}",
                c.name()
            );
        }
    }
}

#[test]
fn corrupt_containers_never_panic() {
    // bit-flip and truncation fuzzing: decompression must return Err (or a
    // wrong-but-well-formed tensor) — never panic, never hang
    let t = mgardp::data::synth::smooth_test_field(&[12, 12, 12]);
    let mut rng = Rng::new(0xFA11);
    for c in all_compressors::<f32>() {
        let bytes = c.compress(&t, Tolerance::Rel(1e-3)).unwrap();
        // truncations
        for frac in [0.1, 0.5, 0.9, 0.99] {
            let cut = (bytes.len() as f64 * frac) as usize;
            let _ = c.decompress(&bytes[..cut]); // must not panic
        }
        // random single-byte corruptions (skip the magic so we exercise deep
        // parsing, not just the header check)
        for _ in 0..40 {
            let mut bad = bytes.clone();
            let pos = 5 + rng.below(bad.len() - 5);
            bad[pos] ^= 1 << rng.below(8);
            let _ = c.decompress(&bad); // must not panic
        }
    }
}

#[test]
fn huffman_random_streams() {
    let mut rng = Rng::new(0x4875);
    for _ in 0..30 {
        let n = rng.below(5000);
        let spread = 1 + rng.below(3000) as u32;
        let data: Vec<u32> = (0..n).map(|_| rng.below(spread as usize) as u32).collect();
        let enc = huffman_encode(&data);
        assert_eq!(huffman_decode(&enc).unwrap(), data);
    }
}

#[test]
fn tolerance_monotonicity_random() {
    // tighter tolerance never produces a *smaller* compressed payload by
    // more than noise, and never a worse error
    let mut rng = Rng::new(0x3011);
    let shape = random_shape(&mut rng);
    let u64field = random_field(&shape, &mut rng);
    let u = Tensor::<f32>::from_vec(
        &shape,
        u64field.data().iter().map(|&v| v as f32).collect(),
    )
    .unwrap();
    for c in all_compressors::<f32>() {
        let mut prev_err = f64::INFINITY;
        for rel in [1e-1, 1e-2, 1e-3, 1e-4] {
            let bytes = c.compress(&u, Tolerance::Rel(rel)).unwrap();
            let back = c.decompress(&bytes).unwrap();
            let err = linf_error(u.data(), back.data());
            assert!(
                err <= prev_err * (1.0 + 1e-9) + 1e-12,
                "{}: error must not grow as τ shrinks ({err} after {prev_err})",
                c.name()
            );
            prev_err = err;
        }
    }
}

#[test]
fn refactor_store_random_fields() {
    let mut rng = Rng::new(0x5704);
    let dir = std::env::temp_dir().join(format!("mgardp_prop_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = mgardp::coordinator::refactor::RefactorStore::create(&dir).unwrap();
    for trial in 0..5 {
        let shape = random_shape(&mut rng);
        let u64field = random_field(&shape, &mut rng);
        let u = Tensor::<f32>::from_vec(
            &shape,
            u64field.data().iter().map(|&v| v as f32).collect(),
        )
        .unwrap();
        let name = format!("f{trial}");
        let m = store.write_field(&name, &u, 1).unwrap();
        let back: Tensor<f32> = store.reconstruct(&name, m.max_level).unwrap();
        let scale = value_range(u.data()).max(1.0) as f64;
        assert!(
            linf_error(u.data(), back.data()) < 1e-3 * scale,
            "trial {trial} {shape:?}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
