//! The streaming/out-of-core contract: a raw-file-backed field compressed
//! under a memory budget smaller than the field yields a container
//! byte-identical to the in-core chunked path; region decompression decodes
//! only intersecting blocks yet honours the global L∞ bound; truncated
//! containers error cleanly at open.

use mgardp::chunk::{ChunkedCompressor, ChunkedConfig};
use mgardp::compressors::{decompress_any_from, Compressor, MgardPlus, Tolerance};
use mgardp::data::{io, synth};
use mgardp::error::Error;
use mgardp::metrics::linf_error;
use mgardp::stream::{compress_to_writer, RawFileSource, StreamConfig, StreamingDecompressor};
use mgardp::tensor::Tensor;
use std::path::PathBuf;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mgardp_streamtest_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn stream_cfg(
    block: &[usize],
    threads: usize,
    budget: usize,
    spool: Option<PathBuf>,
) -> StreamConfig {
    StreamConfig {
        chunk: ChunkedConfig {
            block_shape: block.to_vec(),
            threads,
            ..Default::default()
        },
        memory_budget: budget,
        spool_dir: spool,
    }
}

/// Compress `t` both ways — in-core `ChunkedCompressor` and the streaming
/// writer over a raw file on disk — and require byte-identical containers.
fn assert_byte_identity(t: &Tensor<f32>, block: &[usize], budget: usize, tag: &str) -> Vec<u8> {
    let dir = tmp_dir(tag);
    let raw = dir.join("field.f32");
    io::write_raw(&raw, t).unwrap();

    let codec = MgardPlus::default().chunked(ChunkedConfig {
        block_shape: block.to_vec(),
        threads: 3,
        ..Default::default()
    });
    let want = codec.compress(t, Tolerance::Rel(1e-3)).unwrap();

    let source = RawFileSource::<f32>::new(&raw, t.shape()).unwrap();
    let out_path = dir.join("streamed.mgrp");
    let sink = std::io::BufWriter::new(std::fs::File::create(&out_path).unwrap());
    let written = compress_to_writer(
        &MgardPlus::default(),
        &source,
        Tolerance::Rel(1e-3),
        &stream_cfg(block, 3, budget, Some(dir.clone())),
        sink,
    )
    .unwrap();
    let got = std::fs::read(&out_path).unwrap();
    assert_eq!(written as usize, got.len());
    assert_eq!(got, want, "streamed container differs ({tag})");
    std::fs::remove_dir_all(&dir).ok();
    want
}

#[test]
fn byte_identity_1d_with_remainder() {
    let t = synth::smooth_test_field(&[107]);
    // budget far below the 428-byte-per-block scale: window of 1–2 blocks
    assert_byte_identity(&t, &[16], 256, "1d");
}

#[test]
fn byte_identity_2d_with_remainder() {
    let t = synth::smooth_test_field(&[33, 49]);
    assert_byte_identity(&t, &[16, 16], 4 * 1024, "2d");
}

#[test]
fn byte_identity_3d_17_33_65() {
    // the canonical remainder-heavy shape: merged (17), merged-tail
    // (16+17) and multi-block (16+16+16+17) dimensions at once, under a
    // budget (64 KiB) far below the 1.4 MiB field
    let t = synth::smooth_test_field(&[17, 33, 65]);
    assert_byte_identity(&t, &[16, 16, 16], 64 * 1024, "3d");
}

#[test]
fn region_decode_matches_full_and_honours_bound() {
    let dir = tmp_dir("region");
    let t = synth::smooth_test_field(&[17, 33, 65]);
    let codec = MgardPlus::default().chunked(ChunkedConfig {
        block_shape: vec![16],
        threads: 2,
        ..Default::default()
    });
    let bytes = codec.compress(&t, Tolerance::Rel(1e-3)).unwrap();
    let path = dir.join("c.mgrp");
    std::fs::write(&path, &bytes).unwrap();
    let full: Tensor<f32> = codec.decompress(&bytes).unwrap();
    let tau = 1e-3 * t.value_range();

    let f = std::io::BufReader::new(std::fs::File::open(&path).unwrap());
    let mut d = StreamingDecompressor::open(f).unwrap();
    // a box crossing seams in all three dimensions, plus degenerate and
    // aligned boxes
    for (start, shape) in [
        (vec![10, 12, 30], vec![7, 21, 35]),
        (vec![0, 0, 0], vec![17, 33, 65]),
        (vec![16, 16, 16], vec![1, 1, 1]),
        (vec![0, 16, 48], vec![16, 16, 17]),
    ] {
        let region: Tensor<f32> = d.decompress_region(&start, &shape).unwrap();
        // bitwise-identical to the same box sliced out of the full
        // reconstruction: the same blocks decode either way
        assert_eq!(
            region,
            full.block(&start, &shape).unwrap(),
            "region [{start:?} + {shape:?})"
        );
        let direct = t.block(&start, &shape).unwrap();
        assert!(linf_error(direct.data(), region.data()) <= tau * (1.0 + 1e-6));
    }
    // out-of-field regions are rejected
    assert!(d.decompress_region::<f32>(&[10, 0, 0], &[8, 4, 4]).is_err());
    assert!(d.decompress_region::<f32>(&[0, 0], &[4, 4]).is_err());
    assert!(d.decompress_region::<f32>(&[0, 0, 0], &[0, 4, 4]).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn streaming_decompress_to_raw_round_trips() {
    let dir = tmp_dir("to_raw");
    let t = synth::smooth_test_field(&[19, 21, 23]);
    let raw = dir.join("in.f32");
    io::write_raw(&raw, &t).unwrap();
    let source = RawFileSource::<f32>::new(&raw, t.shape()).unwrap();
    let comp = dir.join("c.mgrp");
    let sink = std::io::BufWriter::new(std::fs::File::create(&comp).unwrap());
    compress_to_writer(
        &MgardPlus::default(),
        &source,
        Tolerance::Rel(1e-3),
        &stream_cfg(&[8], 2, 32 * 1024, Some(dir.clone())),
        sink,
    )
    .unwrap();

    let f = std::io::BufReader::new(std::fs::File::open(&comp).unwrap());
    let mut d = StreamingDecompressor::open(f).unwrap();
    let rec = dir.join("rec.f32");
    let mut out = std::fs::File::create(&rec).unwrap();
    let n = d.decompress_to_raw::<f32, _>(&mut out).unwrap();
    assert_eq!(n as usize, t.nbytes());
    drop(out);
    let back: Tensor<f32> = io::read_raw(&rec, t.shape()).unwrap();
    let tau = 1e-3 * t.value_range();
    assert!(linf_error(t.data(), back.data()) <= tau * (1.0 + 1e-6));

    // ... and the streamed reconstruction is bitwise the in-core one
    let codec = ChunkedCompressor::new(
        MgardPlus::default(),
        ChunkedConfig {
            block_shape: vec![8],
            threads: 2,
            ..Default::default()
        },
    );
    let in_core: Tensor<f32> = codec
        .decompress(&std::fs::read(&comp).unwrap())
        .unwrap();
    assert_eq!(back, in_core);

    // decompress_any_from dispatches seekable streams too
    let f2 = std::io::BufReader::new(std::fs::File::open(&comp).unwrap());
    let any: Tensor<f32> = decompress_any_from(f2).unwrap();
    assert_eq!(any, in_core);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mid_stream_truncation_errors_cleanly() {
    let t = synth::smooth_test_field(&[20, 24]);
    let codec = MgardPlus::default().chunked(ChunkedConfig {
        block_shape: vec![8],
        threads: 1,
        ..Default::default()
    });
    let bytes = codec.compress(&t, Tolerance::Rel(1e-3)).unwrap();
    // every prefix of the container: open (or any later decode) must fail
    // with an error, never panic and never succeed
    for cut in 0..bytes.len() {
        let cur = std::io::Cursor::new(bytes[..cut].to_vec());
        match StreamingDecompressor::open(cur) {
            Err(_) => {}
            Ok(mut d) => {
                // if the prefix happened to parse (cut inside trailing
                // padding can't occur — the index byte-range is exact), the
                // data must still fail to decode fully
                let r: Result<Tensor<f32>, Error> = d.decompress();
                assert!(r.is_err(), "truncation at {cut} decoded successfully");
            }
        }
    }
    // the untruncated stream still opens fine
    let mut d = StreamingDecompressor::open(std::io::Cursor::new(bytes.clone())).unwrap();
    let full: Tensor<f32> = d.decompress().unwrap();
    assert_eq!(full.shape(), t.shape());
}

#[test]
fn incomplete_coverage_refused_at_open() {
    // an index that omits a block (field not fully covered) must be
    // rejected at open, not silently zero-filled by decompress_region
    use mgardp::chunk::container::{read_container, write_container};
    let t = synth::smooth_test_field(&[20, 24]);
    let codec = MgardPlus::default().chunked(ChunkedConfig {
        block_shape: vec![8],
        threads: 1,
        ..Default::default()
    });
    let bytes = codec.compress(&t, Tolerance::Rel(1e-3)).unwrap();
    let (header, mut index, blob) = read_container(&bytes).unwrap();
    let mut blobs: Vec<Vec<u8>> = index
        .entries
        .iter()
        .map(|e| blob[e.offset..e.offset + e.len].to_vec())
        .collect();
    let dropped = index.entries.pop().unwrap();
    blobs.pop();
    assert!(dropped.len > 0);
    let bad = write_container::<f32>(&header.shape, header.tau_abs, &index, &blobs);
    let r = StreamingDecompressor::open(std::io::Cursor::new(bad));
    assert!(matches!(r.err(), Some(Error::CorruptStream(_))));
}

#[test]
fn truncated_blob_section_refused_at_open() {
    // a stream physically shorter than the declared blob section must be
    // refused at open, before any block access (the index itself parses)
    let t = synth::smooth_test_field(&[20, 24]);
    let codec = MgardPlus::default().chunked(ChunkedConfig {
        block_shape: vec![8],
        threads: 1,
        ..Default::default()
    });
    let mut bytes = codec.compress(&t, Tolerance::Rel(1e-3)).unwrap();
    bytes.truncate(bytes.len() - 3);
    let r = StreamingDecompressor::open(std::io::Cursor::new(bytes));
    assert!(matches!(r.err(), Some(Error::CorruptStream(_))));
}
