//! Differential suite for the MGSH sharded layouts: a sharded store must
//! be indistinguishable from the unsharded one — byte-identical outputs
//! and identical plans at every tolerance, over every storage backend —
//! while issuing provably fewer ranged reads for (region, τ) queries
//! than the one-read-per-piece layout it replaces.

use mgardp::chunk::ChunkedConfig;
use mgardp::compressors::{decompress_any, Compressor, MgardPlus, Tolerance};
use mgardp::coordinator::refactor::RefactorStore;
use mgardp::data::synth;
use mgardp::metrics::linf_error;
use mgardp::serve::{RemoteField, ServeClient, ServeConfig, Server};
use mgardp::shard::ShardedChunkStore;
use mgardp::storage::{MemoryStorage, MockStorage, Storage};
use mgardp::stream::StreamingDecompressor;
use mgardp::tensor::Tensor;
use std::sync::Arc;
use std::time::Duration;

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("mgardp_shard_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Sharded vs blob layout of the same 3-D refactoring: identical plans
/// and byte-identical reconstructions at every tolerance, over the
/// file, memory and simulated-remote backends.
#[test]
fn sharded_layout_is_byte_identical_to_blob_layout_across_backends() {
    let t = synth::smooth_test_field(&[12, 13, 14]);
    let taus = [0.5, 0.05, 1e-3, f64::MIN_POSITIVE];
    let dir = temp_dir("diff");
    let mut pairs: Vec<(&str, RefactorStore, RefactorStore)> = vec![
        (
            "memory",
            RefactorStore::with_storage(Arc::new(MemoryStorage::new())),
            RefactorStore::with_storage(Arc::new(MemoryStorage::new())),
        ),
        (
            "mock",
            RefactorStore::with_storage(Arc::new(MockStorage::new(
                Arc::new(MemoryStorage::new()),
                Duration::ZERO,
                0,
            ))),
            RefactorStore::with_storage(Arc::new(MockStorage::new(
                Arc::new(MemoryStorage::new()),
                Duration::ZERO,
                0,
            ))),
        ),
    ];
    pairs.push((
        "file",
        RefactorStore::create(dir.join("blob")).unwrap(),
        RefactorStore::create(dir.join("sharded")).unwrap(),
    ));
    for (backend, blob, sharded) in &pairs {
        blob.write_field_progressive("u", &t, None, 3).unwrap();
        sharded
            .write_field_progressive_sharded("u", &t, None, 3, 2048)
            .unwrap();
        // the manifest is layout-independent
        assert_eq!(
            blob.storage().read("u/manifest.bin").unwrap(),
            sharded.storage().read("u/manifest.bin").unwrap(),
            "{backend}: manifests diverge"
        );
        let a = blob.progressive("u").unwrap();
        let b = sharded.progressive("u").unwrap();
        assert!(!a.is_sharded() && b.is_sharded(), "{backend}");
        for tau in taus {
            let (xa, pa): (Tensor<f32>, _) = a.retrieve(tau).unwrap();
            let (xb, pb): (Tensor<f32>, _) = b.retrieve(tau).unwrap();
            assert_eq!(pa, pb, "{backend} τ {tau:.3e}: plans diverge");
            assert_eq!(
                xa.data(),
                xb.data(),
                "{backend} τ {tau:.3e}: outputs diverge"
            );
            assert!(
                linf_error(t.data(), xb.data()) <= tau,
                "{backend} τ {tau:.3e}: certificate violated"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The read-count claim for the progressive layout: the blob layout
/// pays one ranged read per planned component; the sharded layout
/// coalesces each stream's plan prefix into a single run.
#[test]
fn sharded_tolerance_retrieval_reads_fewer_ranges_than_per_component() {
    let t = synth::smooth_test_field(&[12, 13, 14]);
    let mock_blob = Arc::new(MockStorage::new(
        Arc::new(MemoryStorage::new()),
        Duration::ZERO,
        0,
    ));
    let blob = RefactorStore::with_storage(Arc::clone(&mock_blob) as Arc<dyn Storage>);
    blob.write_field_progressive("u", &t, None, 3).unwrap();
    let mock_sh = Arc::new(MockStorage::new(
        Arc::new(MemoryStorage::new()),
        Duration::ZERO,
        0,
    ));
    let sharded = RefactorStore::with_storage(Arc::clone(&mock_sh) as Arc<dyn Storage>);
    sharded
        .write_field_progressive_sharded("u", &t, None, 3, 1 << 20)
        .unwrap();
    let fa = blob.progressive("u").unwrap();
    let fb = sharded.progressive("u").unwrap();
    let nstreams = fa.manifest().streams.len();
    let tau = 1e-3;

    let mut ra = fa.reader::<f32>().unwrap();
    let plan_a = fa.plan(tau, None).unwrap();
    let ncomps = plan_a.components_beyond(&ra.fetched()).len();
    assert!(
        ncomps > nstreams,
        "fixture too small: plan covers {ncomps} components over {nstreams} streams"
    );
    let before = mock_blob.ops();
    fa.refine(&mut ra, &plan_a).unwrap();
    let blob_reads = mock_blob.ops() - before;
    assert_eq!(
        blob_reads, ncomps as u64,
        "blob layout must pay one ranged read per component"
    );

    let mut rb = fb.reader::<f32>().unwrap();
    let plan_b = fb.plan(tau, None).unwrap();
    assert_eq!(plan_a, plan_b);
    let before = mock_sh.ops();
    fb.refine(&mut rb, &plan_b).unwrap();
    let sharded_reads = mock_sh.ops() - before;
    assert!(
        sharded_reads < blob_reads,
        "sharded retrieval issued {sharded_reads} reads, blob layout {blob_reads}"
    );
    assert!(
        sharded_reads <= nstreams as u64,
        "expected at most one coalesced run per stream prefix, got {sharded_reads}"
    );
    // and the cheaper fetch reconstructs the identical field
    assert_eq!(
        ra.reconstruct().unwrap().data(),
        rb.reconstruct().unwrap().data()
    );
}

/// Region decode over a sharded 3-D chunked container: byte-identical
/// to the streaming region decoder, with fewer ranged reads than the
/// one-read-per-block lower bound of a per-object layout, and shards
/// holding no intersecting block never touched.
#[test]
fn sharded_chunk_region_decode_matches_streaming_with_fewer_reads() {
    let t = synth::smooth_test_field(&[24, 20, 16]);
    let codec = MgardPlus::default().chunked(ChunkedConfig {
        block_shape: vec![8, 8, 8],
        threads: 1,
        ..Default::default()
    });
    let container = codec.compress(&t, Tolerance::Rel(1e-3)).unwrap();
    let mem = Arc::new(MemoryStorage::new());
    let nshards = ShardedChunkStore::write(&*mem, "c", &container, 2048).unwrap();
    assert!(nshards > 1, "fixture too small: one shard defeats the test");
    let mock = Arc::new(MockStorage::new(mem, Duration::ZERO, 0));
    let store = ShardedChunkStore::open(Arc::clone(&mock) as Arc<dyn Storage>, "c").unwrap();
    assert_eq!(store.nshards(), nshards);

    // a seam-crossing region intersecting a 3×2×2 sub-grid of blocks
    let (start, shape) = ([5usize, 3, 2], [14usize, 12, 10]);
    let nhit = store
        .index()
        .entries
        .iter()
        .filter(|e| {
            (0..3).all(|d| {
                start[d] < e.start[d] + e.shape[d] && e.start[d] < start[d] + shape[d]
            })
        })
        .count();
    assert!(nhit >= 8, "region only hits {nhit} blocks");
    let before = mock.ops();
    let region: Tensor<f32> = store.decompress_region(&start, &shape).unwrap();
    let reads = mock.ops() - before;
    assert!(
        reads < nhit as u64,
        "sharded region decode issued {reads} reads over {nhit} intersecting blocks \
         — no better than one object per block"
    );

    // byte-identical to the streaming decoder over the unsharded container
    let mut d = StreamingDecompressor::open(std::io::Cursor::new(container.clone())).unwrap();
    let direct: Tensor<f32> = d.decompress_region(&start, &shape).unwrap();
    assert_eq!(region.data(), direct.data());
    // the crop honours the container tolerance pointwise
    let tau = 1e-3 * t.value_range();
    let truth = t.block(&start, &shape).unwrap();
    assert!(linf_error(truth.data(), region.data()) <= tau * (1.0 + 1e-6));
    // and the full-field decode matches the in-core decoder byte for byte
    let full: Tensor<f32> = store.decompress().unwrap();
    let base: Tensor<f32> = decompress_any(&container).unwrap();
    assert_eq!(full.data(), base.data());
}

/// The serve daemon over a sharded field: the cache keys name physical
/// shard ranges, plans and certificates are preserved end to end, and
/// server-side region retrieval works unchanged.
#[test]
fn serve_daemon_over_a_sharded_field_preserves_certificates() {
    let t = synth::smooth_test_field(&[17, 18]);
    let store = RefactorStore::with_storage(Arc::new(MemoryStorage::new()));
    store
        .write_field_progressive_sharded("u", &t, None, 3, 1024)
        .unwrap();
    let field = store.progressive("u").unwrap();
    assert!(field.is_sharded());
    let server = Server::start(field, &ServeConfig::default()).unwrap();

    let mut remote: RemoteField<f32> = RemoteField::open(server.addr()).unwrap();
    let (back, plan) = remote.refine(1e-3).unwrap();
    assert!(plan.certified_bound <= 1e-3);
    assert!(linf_error(t.data(), back.data()) <= 1e-3);

    // server-side region retrieve over the sharded layout
    let mut client = ServeClient::connect(server.addr()).unwrap();
    let (crop, bound): (Tensor<f32>, f64) = client.retrieve(0.05, Some(&[(3, 9), (4, 8)])).unwrap();
    assert!(bound <= 0.05);
    assert_eq!(crop.shape(), &[9, 8]);
    let truth = t.block(&[3, 4], &[9, 8]).unwrap();
    assert!(linf_error(truth.data(), crop.data()) <= 0.05);
}
