//! Pin the container format to the normative spec: the worked hex example
//! in `docs/FORMAT.md` must match, byte for byte, what the real emitter
//! (`chunk::container::write_container`) produces for the documented
//! inputs — and the document itself must contain exactly these bytes, so
//! the spec cannot drift from the code.

use mgardp::chunk::container::{write_container, BlockEntry, ChunkIndex, TilingPolicy};
use mgardp::chunk::{CHUNK_CONTAINER_VERSION, CHUNK_CONTAINER_VERSION_ADAPTIVE};
use mgardp::compressors::{Header, Method};
use mgardp::coordinator::refactor::{Manifest, REFACTOR_MANIFEST_VERSION};
use mgardp::progressive::{
    ProgressiveManifest, StreamMeta, PROGRESSIVE_MANIFEST_VERSION,
};
use mgardp::shard::{read_shard, ShardIndex, ShardWriter, SHARD_VERSION};

/// The adaptive worked example of docs/FORMAT.md, 105 bytes.
const ADAPTIVE_EXAMPLE_HEX: &str = "\
4d 47 52 50 01 06 01 02 06 04 00 00 00 00 00 00
e0 3f 02 02 04 04 01 02 02 00 00 00 00 00 00 d0
3f 02 00 14 00 00 04 04 02 00 00 00 00 00 00 e0
3f 14 14 04 00 02 04 01 00 00 00 00 00 00 e0 3f
28 4d 47 52 50 01 02 01 02 04 04 00 00 00 00 00
00 e0 3f 41 41 4d 47 52 50 01 02 01 02 02 04 00
00 00 00 00 00 e0 3f 42 42";

/// The fixed counterpart of docs/FORMAT.md, 94 bytes.
const FIXED_EXAMPLE_HEX: &str = "\
4d 47 52 50 01 06 01 02 06 04 00 00 00 00 00 00
e0 3f 01 02 04 04 02 00 14 00 00 04 04 02 00 00
00 00 00 00 e0 3f 14 14 04 00 02 04 01 00 00 00
00 00 00 e0 3f 28 4d 47 52 50 01 02 01 02 04 04
00 00 00 00 00 00 e0 3f 41 41 4d 47 52 50 01 02
01 02 02 04 00 00 00 00 00 00 e0 3f 42 42";

fn parse_hex(s: &str) -> Vec<u8> {
    s.split_whitespace()
        .map(|b| u8::from_str_radix(b, 16).expect("hex byte"))
        .collect()
}

/// A well-formed inner mgard+ blob: the shared header for the block shape
/// plus a 2-byte stand-in payload, exactly as documented.
fn inner_blob(shape: &[usize], payload: &[u8]) -> Vec<u8> {
    let mut b = Vec::new();
    Header {
        method: Method::MgardPlus,
        dtype: 1,
        shape: shape.to_vec(),
        tau_abs: 0.5,
    }
    .write(&mut b);
    b.extend_from_slice(payload);
    b
}

fn example_blobs_and_entries() -> (Vec<Vec<u8>>, Vec<BlockEntry>) {
    let blobs = vec![inner_blob(&[4, 4], b"AA"), inner_blob(&[2, 4], b"BB")];
    let entries = vec![
        BlockEntry {
            offset: 0,
            len: blobs[0].len(),
            start: vec![0, 0],
            shape: vec![4, 4],
            nlevels: 2,
            tau_abs: 0.5,
        },
        BlockEntry {
            offset: blobs[0].len(),
            len: blobs[1].len(),
            start: vec![4, 0],
            shape: vec![2, 4],
            nlevels: 1,
            tau_abs: 0.5,
        },
    ];
    (blobs, entries)
}

#[test]
fn adaptive_worked_example_matches_emitter() {
    let (blobs, entries) = example_blobs_and_entries();
    let index = ChunkIndex {
        inner: Method::MgardPlus,
        block_shape: vec![4, 4],
        policy: TilingPolicy::VarianceGuided {
            min_block_shape: vec![2, 2],
            variance_threshold: 0.25,
        },
        entries,
    };
    let bytes = write_container::<f32>(&[6, 4], 0.5, &index, &blobs);
    assert_eq!(bytes, parse_hex(ADAPTIVE_EXAMPLE_HEX), "spec hex drifted from emitter");
    // and the documented container parses back to the documented inputs
    let (header, back, blob) = mgardp::chunk::container::read_container(&bytes).unwrap();
    assert_eq!(header.shape, vec![6, 4]);
    assert_eq!(header.tau_abs, 0.5);
    assert_eq!(back.policy, index.policy);
    assert_eq!(back.entries, index.entries);
    assert_eq!(blob.len(), 40);
}

#[test]
fn fixed_worked_example_matches_emitter() {
    let (blobs, entries) = example_blobs_and_entries();
    let index = ChunkIndex {
        inner: Method::MgardPlus,
        block_shape: vec![4, 4],
        policy: TilingPolicy::Fixed,
        entries,
    };
    let bytes = write_container::<f32>(&[6, 4], 0.5, &index, &blobs);
    assert_eq!(bytes, parse_hex(FIXED_EXAMPLE_HEX), "spec hex drifted from emitter");
    // the fixed example is exactly what the fixed partition produces
    let tiles = mgardp::chunk::partition(&[6, 4], &[4, 4]).unwrap();
    let tile_geom: Vec<(Vec<usize>, Vec<usize>)> =
        tiles.into_iter().map(|b| (b.start, b.shape)).collect();
    let entry_geom: Vec<(Vec<usize>, Vec<usize>)> = index
        .entries
        .iter()
        .map(|e| (e.start.clone(), e.shape.clone()))
        .collect();
    assert_eq!(tile_geom, entry_geom);
}

#[test]
fn sub_version_bytes_match_spec_constants() {
    let adaptive = parse_hex(ADAPTIVE_EXAMPLE_HEX);
    let fixed = parse_hex(FIXED_EXAMPLE_HEX);
    // the sub-version byte sits right after the 18-byte shared header of
    // the [6, 4] example
    assert_eq!(adaptive[18], CHUNK_CONTAINER_VERSION_ADAPTIVE);
    assert_eq!(fixed[18], CHUNK_CONTAINER_VERSION);
    // the two containers differ only by the 11 policy bytes
    assert_eq!(adaptive.len(), fixed.len() + 11);
}

/// The progressive-manifest worked example of docs/FORMAT.md, 128 bytes:
/// an f32 field of shape `[5]`, levels 0..=1, 2 magnitude planes,
/// `c_linf = 2.0`, two streams (3 and 2 coefficients).
const PROGRESSIVE_MANIFEST_EXAMPLE_HEX: &str = "\
4d 47 50 52 01 01 01 05 00 01 02 00 00 00 00 00
00 00 40 02 03 00 00 00 00 00 00 f8 3f 02 01 01
01 0d 00 00 00 00 00 00 f8 3f 00 00 00 00 00 00
f8 3f 00 00 00 00 00 00 f0 3f 00 00 00 00 00 00
e0 3f 00 00 00 00 00 00 00 00 02 00 00 00 00 00
00 e8 3f 00 01 01 01 09 00 00 00 00 00 00 e8 3f
00 00 00 00 00 00 e8 3f 00 00 00 00 00 00 e0 3f
00 00 00 00 00 00 d0 3f 00 00 00 00 00 00 00 00";

/// The level-manifest worked example of docs/FORMAT.md, 13 bytes: the
/// same `[5]` field in the level layout with components of 7 and 9 bytes.
const LEVEL_MANIFEST_EXAMPLE_HEX: &str = "\
4d 47 52 46 01 01 01 05 00 01 02 07 09";

/// The documented progressive manifest as a struct.
fn progressive_manifest_example() -> ProgressiveManifest {
    ProgressiveManifest {
        shape: vec![5],
        dtype: 1,
        start_level: 0,
        max_level: 1,
        planes: 2,
        c_linf: 2.0,
        streams: vec![
            StreamMeta {
                n: 3,
                max_abs: 1.5,
                exponent: 1,
                comp_lens: vec![1, 1, 1, 13],
                err_after: vec![1.5, 1.5, 1.0, 0.5, 0.0],
            },
            StreamMeta {
                n: 2,
                max_abs: 0.75,
                exponent: 0,
                comp_lens: vec![1, 1, 1, 9],
                err_after: vec![0.75, 0.75, 0.5, 0.25, 0.0],
            },
        ],
    }
}

#[test]
fn progressive_manifest_worked_example_matches_emitter() {
    let m = progressive_manifest_example();
    let bytes = m.to_bytes();
    assert_eq!(
        bytes,
        parse_hex(PROGRESSIVE_MANIFEST_EXAMPLE_HEX),
        "spec hex drifted from the progressive manifest emitter"
    );
    // the documented bytes parse back to the documented manifest
    assert_eq!(ProgressiveManifest::from_bytes(&bytes).unwrap(), m);
    // and the version byte sits where the spec says (right after magic)
    assert_eq!(bytes[4], PROGRESSIVE_MANIFEST_VERSION);
    assert_eq!(&bytes[..4], b"MGPR");
    // component ranges tile components.bin exactly as documented
    assert_eq!(m.component_range(0, 0).unwrap(), (0, 1));
    assert_eq!(m.component_range(0, 3).unwrap(), (3, 13));
    assert_eq!(m.component_range(1, 0).unwrap(), (16, 1));
    assert_eq!(m.total_bytes(), 28);
}

#[test]
fn level_manifest_worked_example_matches_emitter() {
    let m = Manifest {
        shape: vec![5],
        dtype: 1,
        start_level: 0,
        max_level: 1,
        component_bytes: vec![7, 9],
    };
    let bytes = m.to_bytes();
    assert_eq!(
        bytes,
        parse_hex(LEVEL_MANIFEST_EXAMPLE_HEX),
        "spec hex drifted from the level manifest emitter"
    );
    assert_eq!(Manifest::from_bytes(&bytes).unwrap(), m);
    assert_eq!(bytes[4], REFACTOR_MANIFEST_VERSION);
    assert_eq!(&bytes[..4], b"MGRF");
    // the PR-era encoding is exactly the versioned body without the
    // 5-byte magic + version prefix, and still parses
    assert_eq!(Manifest::from_bytes(&bytes[5..]).unwrap(), m);
}

/// The MGSH components-kind worked example of docs/FORMAT.md, 50 bytes:
/// two components (stream 0, comps 0 and 1) of 2 and 1 payload bytes
/// with err_after 0.5 and 0.25.
const SHARD_COMPONENTS_EXAMPLE_HEX: &str = "\
aa bb cc 02 02 00 00 00 02 00 00 00 00 00 00 e0
3f 00 01 02 01 00 00 00 00 00 00 d0 3f 03 00 00
00 00 00 00 00 1a 00 00 00 00 00 00 00 01 4d 47
53 48";

/// The MGSH blocks-kind worked example of docs/FORMAT.md, 39 bytes: one
/// rank-1 block (id 0, start [4], shape [5], tau 0.5) with a 2-byte blob.
const SHARD_BLOCKS_EXAMPLE_HEX: &str = "\
ab cd 01 01 01 00 00 02 04 05 00 00 00 00 00 00
e0 3f 02 00 00 00 00 00 00 00 10 00 00 00 00 00
00 00 01 4d 47 53 48";

#[test]
fn shard_components_worked_example_matches_emitter() {
    let mut w = ShardWriter::components();
    w.push_component(0, 0, 0.5, &[0xAA, 0xBB]).unwrap();
    w.push_component(0, 1, 0.25, &[0xCC]).unwrap();
    let bytes = w.finish().unwrap();
    assert_eq!(
        bytes,
        parse_hex(SHARD_COMPONENTS_EXAMPLE_HEX),
        "spec hex drifted from the shard emitter"
    );
    // the documented bytes parse back to the documented entries
    let (index, payload) = read_shard(&bytes).unwrap();
    assert_eq!(payload, &[0xAA, 0xBB, 0xCC]);
    match index {
        ShardIndex::Components { entries } => {
            assert_eq!(entries.len(), 2);
            assert_eq!((entries[0].offset, entries[0].len), (0, 2));
            assert_eq!(entries[0].err_after, 0.5);
            assert_eq!((entries[1].stream, entries[1].comp), (0, 1));
            assert_eq!((entries[1].offset, entries[1].len), (2, 1));
            assert_eq!(entries[1].err_after, 0.25);
        }
        other => panic!("wrong index kind: {other:?}"),
    }
    // footer fields sit where the spec says: trailing magic, version
    // before it, index_off/index_len LE at the footer start
    let n = bytes.len();
    assert_eq!(&bytes[n - 4..], b"MGSH");
    assert_eq!(bytes[n - 5], SHARD_VERSION);
    assert_eq!(&bytes[n - 21..n - 13], &3u64.to_le_bytes());
    assert_eq!(&bytes[n - 13..n - 5], &26u64.to_le_bytes());
}

#[test]
fn shard_blocks_worked_example_matches_emitter() {
    let mut w = ShardWriter::blocks(1);
    w.push_block(0, &[4], &[5], 0.5, &[0xAB, 0xCD]).unwrap();
    let bytes = w.finish().unwrap();
    assert_eq!(
        bytes,
        parse_hex(SHARD_BLOCKS_EXAMPLE_HEX),
        "spec hex drifted from the shard emitter"
    );
    let (index, payload) = read_shard(&bytes).unwrap();
    assert_eq!(payload, &[0xAB, 0xCD]);
    match index {
        ShardIndex::Blocks { ndim, entries } => {
            assert_eq!(ndim, 1);
            assert_eq!(entries.len(), 1);
            assert_eq!(entries[0].block_id, 0);
            assert_eq!(entries[0].start, vec![4]);
            assert_eq!(entries[0].shape, vec![5]);
            assert_eq!(entries[0].tau_abs, 0.5);
        }
        other => panic!("wrong index kind: {other:?}"),
    }
}

#[test]
fn format_md_contains_exactly_these_bytes() {
    let doc = include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/docs/FORMAT.md"));
    let normalized: String = doc
        .chars()
        .filter(|c| !c.is_whitespace())
        .collect::<String>()
        .to_ascii_lowercase();
    for (name, hex) in [
        ("adaptive", ADAPTIVE_EXAMPLE_HEX),
        ("fixed", FIXED_EXAMPLE_HEX),
        ("progressive manifest", PROGRESSIVE_MANIFEST_EXAMPLE_HEX),
        ("level manifest", LEVEL_MANIFEST_EXAMPLE_HEX),
        ("shard components", SHARD_COMPONENTS_EXAMPLE_HEX),
        ("shard blocks", SHARD_BLOCKS_EXAMPLE_HEX),
    ] {
        let needle: String = hex.split_whitespace().collect();
        assert!(
            normalized.contains(&needle),
            "docs/FORMAT.md no longer contains the {name} worked example bytes"
        );
    }
}
