//! Allocation-discipline harness for the fused hot path.
//!
//! A counting global allocator measures heap-allocation *counts* (not
//! bytes) around compression calls and pins the scratch-reuse contract:
//!
//! * steady-state chunked compression performs **O(1) allocations per
//!   block** — the marginal per-block count is independent of how many
//!   blocks a field has and stays under a fixed budget;
//! * reusing a [`CodecScratch`] across calls strictly reduces allocations
//!   after warm-up and **never changes the output bytes**;
//! * [`DecomposeScratch`] reuse at the decomposer layer is likewise
//!   allocation-bounded and value-transparent.
//!
//! The per-block budget below is a regression tripwire, not an exact
//! count: it is sized so that re-introducing per-level stream buffers,
//! per-sweep temporaries or (worse) per-element allocations trips it,
//! while platform/allocator noise does not. Everything runs inside one
//! `#[test]` so no concurrent test thread pollutes the counters.

use mgardp::compressors::{CodecScratch, Compressor, MgardPlus, MgardPlusConfig, Tolerance};
use mgardp::decompose::{DecomposeScratch, Decomposer, OptFlags};
use mgardp::grid::Hierarchy;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Allocation count of one closure run.
fn allocs_of(mut f: impl FnMut()) -> u64 {
    let before = ALLOC_COUNT.load(Ordering::Relaxed);
    f();
    ALLOC_COUNT.load(Ordering::Relaxed) - before
}

/// Fused, non-adaptive MGARD+ — the hot path under test. Adaptive
/// termination is off so every block takes the fused single pass.
fn hot_codec() -> MgardPlus {
    MgardPlus::new(MgardPlusConfig {
        adaptive: false,
        ..MgardPlusConfig::default()
    })
}

/// Marginal allocations per block of a chunked compression, measured by
/// differencing two fields with the same block size but different block
/// counts (the per-call fixed overhead — scratch warm-up, container
/// assembly — cancels out).
fn marginal_allocs_per_block() -> f64 {
    let codec = hot_codec().chunked(mgardp::chunk::ChunkedConfig {
        block_shape: vec![8],
        threads: 1, // sequential pool path: one scratch serves every block
        tiling: mgardp::chunk::Tiling::Fixed,
    });
    let small = mgardp::data::synth::smooth_test_field(&[16, 16, 16]); // 8 blocks
    let large = mgardp::data::synth::smooth_test_field(&[32, 32, 32]); // 64 blocks
    // warm once so lazily-initialized globals (huffman tables etc.) don't
    // skew the small run
    let _ = codec.compress(&small, Tolerance::Abs(1e-3)).unwrap();
    let a_small = allocs_of(|| {
        let _ = codec.compress(&small, Tolerance::Abs(1e-3)).unwrap();
    });
    let a_large = allocs_of(|| {
        let _ = codec.compress(&large, Tolerance::Abs(1e-3)).unwrap();
    });
    (a_large.saturating_sub(a_small)) as f64 / (64 - 8) as f64
}

#[test]
fn steady_state_allocation_budget_and_scratch_transparency() {
    // --- O(1) allocations per block in steady state ---------------------
    let per_block = marginal_allocs_per_block();
    assert!(
        per_block > 0.0,
        "marginal allocation measurement degenerate: {per_block}"
    );
    // Budget: the fused path costs ~100–150 allocations per 8³ block
    // (block gather, pad, external coarse codec, huffman, lossless stage,
    // container assembly). 320 leaves room for allocator noise while
    // catching any per-level or per-element regression (a single
    // re-introduced per-sweep buffer adds ~2 × levels × dims ≈ 20+; a
    // per-element path adds 500+).
    assert!(
        per_block <= 320.0,
        "steady-state chunked compression allocates {per_block:.1} times per block \
         (budget: 320) — per-block allocation discipline regressed"
    );

    // --- scratch reuse strictly reduces allocations after warm-up -------
    let t = mgardp::data::synth::smooth_test_field(&[17, 17, 17]);
    let codec = hot_codec();
    let mut ws = CodecScratch::<f32>::new();
    let mut first_bytes = Vec::new();
    let cold = allocs_of(|| {
        first_bytes = codec
            .compress_scratch(&t, Tolerance::Abs(1e-3), &mut ws)
            .unwrap();
    });
    let mut warm_bytes = Vec::new();
    let warm = allocs_of(|| {
        warm_bytes = codec
            .compress_scratch(&t, Tolerance::Abs(1e-3), &mut ws)
            .unwrap();
    });
    assert!(
        warm < cold,
        "warm scratch call allocated {warm} times, cold {cold}: reuse is not kicking in"
    );

    // --- scratch reuse never changes output bytes -----------------------
    assert_eq!(first_bytes, warm_bytes, "scratch reuse changed the container bytes");
    let fresh = codec.compress(&t, Tolerance::Abs(1e-3)).unwrap();
    assert_eq!(fresh, warm_bytes, "scratch path differs from fresh-scratch path");

    // --- decomposer-layer scratch: bounded and value-transparent --------
    let u2 = mgardp::data::synth::smooth_test_field(&[33, 33]);
    let h = Hierarchy::new(&[33, 33], None).unwrap();
    let dz = Decomposer::new(h, OptFlags::all()).unwrap();
    let mut ds = DecomposeScratch::<f32>::new();
    let reference = dz.decompose(&u2).unwrap();
    let _ = dz.decompose_scratch(&u2, &mut ds).unwrap(); // warm
    let mut reused = None;
    let warm_dz = allocs_of(|| {
        reused = Some(dz.decompose_scratch(&u2, &mut ds).unwrap());
    });
    let reused = reused.unwrap();
    assert_eq!(reference.coarse.data(), reused.coarse.data());
    assert_eq!(reference.coeffs, reused.coeffs);
    // A warm decompose allocates only what escapes (input copy, coarse
    // tensor, one stream per level plus growth) and the small per-call
    // index/shape vectors — ~100 for 33×33. The budget is a tripwire for
    // anything per-element (1089 points here would blow straight past it).
    assert!(
        warm_dz <= 192,
        "warm decompose_scratch allocated {warm_dz} times (budget: 192)"
    );
    let mut recomposed = None;
    let warm_rz = allocs_of(|| {
        recomposed = Some(dz.recompose_scratch(&reused, &mut ds).unwrap());
    });
    let back = recomposed.unwrap();
    let direct = dz.recompose(&reference).unwrap();
    assert_eq!(direct.data(), back.data(), "recompose scratch reuse changed values");
    assert!(
        warm_rz <= 192,
        "warm recompose_scratch allocated {warm_rz} times (budget: 192)"
    );
}
