//! Tier-1 suite for variance-guided adaptive tiling: byte identity between
//! the in-core and streamed writers on adaptive layouts, the threshold-0
//! fixed-tiling degenerate, seam error bounds across unequal neighboring
//! blocks, and region decode on heterogeneous layouts.

use mgardp::chunk::{container, ChunkedConfig, Tiling, TilingPolicy};
use mgardp::compressors::{decompress_any, Compressor, MgardPlus, Tolerance};
use mgardp::data::{io, synth};
use mgardp::metrics::linf_error;
use mgardp::stream::{
    compress_to_writer, InCoreSource, RawFileSource, StreamConfig, StreamingDecompressor,
};
use mgardp::tensor::Tensor;

fn adaptive(block: &[usize], min: &[usize], threshold: f64, threads: usize) -> ChunkedConfig {
    ChunkedConfig {
        block_shape: block.to_vec(),
        threads,
        tiling: Tiling::Adaptive {
            min_block_shape: min.to_vec(),
            variance_threshold: threshold,
        },
    }
}

#[test]
fn threshold_zero_is_bit_exact_fixed_tiling() {
    let t = synth::split_test_field(&[21, 22], 3);
    let fixed = MgardPlus::default().chunked(ChunkedConfig {
        block_shape: vec![8],
        threads: 2,
        tiling: Tiling::Fixed,
    });
    let zero = MgardPlus::default().chunked(adaptive(&[8], &[4], 0.0, 2));
    let want = fixed.compress(&t, Tolerance::Rel(1e-3)).unwrap();
    let got = zero.compress(&t, Tolerance::Rel(1e-3)).unwrap();
    assert_eq!(got, want, "threshold 0 must reproduce the fixed container");
    let (_, index, _) = container::read_container(&got).unwrap();
    assert_eq!(index.policy, TilingPolicy::Fixed);
}

#[test]
fn uniform_field_collapses_to_one_block() {
    let t = Tensor::<f32>::from_fn(&[20, 24], |_| 1.5);
    let codec = MgardPlus::default().chunked(adaptive(&[8], &[4], 0.5, 1));
    let bytes = codec.compress(&t, Tolerance::Abs(1e-3)).unwrap();
    let (header, index, _) = container::read_container(&bytes).unwrap();
    assert_eq!(index.entries.len(), 1);
    assert_eq!(index.entries[0].start, vec![0, 0]);
    assert_eq!(index.entries[0].shape, header.shape);
    let back: Tensor<f32> = codec.decompress(&bytes).unwrap();
    assert!(linf_error(t.data(), back.data()) <= 1e-3);
}

#[test]
fn adaptive_layout_refines_and_honours_seam_bound() {
    // unequal neighboring blocks: the turbulent half splits to 4³-ish tiles
    // while the smooth half stays coarse, so seams join blocks of different
    // sizes — the global L∞ bound must hold pointwise across all of them
    let t = synth::split_test_field(&[33, 32, 18], 11);
    let tau = 1e-3 * t.value_range();
    let codec = MgardPlus::default().chunked(adaptive(&[16], &[4], 0.4, 4));
    let bytes = codec.compress(&t, Tolerance::Rel(1e-3)).unwrap();
    let (_, index, _) = container::read_container(&bytes).unwrap();
    assert!(
        index.entries.len() > 1,
        "split field must refine into multiple blocks"
    );
    let sizes: Vec<usize> = index
        .entries
        .iter()
        .map(|e| e.shape.iter().product::<usize>())
        .collect();
    let smallest = *sizes.iter().min().unwrap();
    let largest = *sizes.iter().max().unwrap();
    assert!(
        largest > smallest,
        "expected heterogeneous block sizes, got {sizes:?}"
    );
    let back: Tensor<f32> = codec.decompress(&bytes).unwrap();
    assert!(linf_error(t.data(), back.data()) <= tau * (1.0 + 1e-6));
    // the self-dispatching path agrees
    let any: Tensor<f32> = decompress_any(&bytes).unwrap();
    assert_eq!(any, back);
}

#[test]
fn streamed_adaptive_container_is_byte_identical() {
    let t = synth::split_test_field(&[21, 22, 23], 5);
    let codec = MgardPlus::default().chunked(adaptive(&[10], &[4], 0.4, 2));
    let want = codec.compress(&t, Tolerance::Rel(1e-3)).unwrap();

    // in-core source through the streaming writer
    let cfg = StreamConfig {
        chunk: adaptive(&[10], &[4], 0.4, 2),
        memory_budget: 64 * 1024,
        spool_dir: None,
    };
    let mut from_core = Vec::new();
    compress_to_writer(
        &MgardPlus::default(),
        &InCoreSource::new(&t),
        Tolerance::Rel(1e-3),
        &cfg,
        &mut from_core,
    )
    .unwrap();
    assert_eq!(from_core, want, "in-core source streamed container differs");

    // raw file on disk through the streaming writer (strided cell reads)
    let dir = std::env::temp_dir().join(format!("mgardp_adapt_stream_{}", std::process::id()));
    let raw = dir.join("field.f32");
    io::write_raw(&raw, &t).unwrap();
    let source = RawFileSource::<f32>::new(&raw, t.shape()).unwrap();
    let mut from_file = Vec::new();
    compress_to_writer(
        &MgardPlus::default(),
        &source,
        Tolerance::Rel(1e-3),
        &cfg,
        &mut from_file,
    )
    .unwrap();
    assert_eq!(from_file, want, "raw-file source streamed container differs");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn streaming_decompressor_handles_adaptive_layouts() {
    let t = synth::split_test_field(&[24, 26], 13);
    let tau = 1e-3 * t.value_range();
    let codec = MgardPlus::default().chunked(adaptive(&[8], &[4], 0.4, 1));
    let bytes = codec.compress(&t, Tolerance::Rel(1e-3)).unwrap();
    let mut d = StreamingDecompressor::open(std::io::Cursor::new(bytes)).unwrap();
    assert!(matches!(
        d.index().policy,
        TilingPolicy::VarianceGuided { .. }
    ));
    // full decode
    let back: Tensor<f32> = d.decompress().unwrap();
    assert!(linf_error(t.data(), back.data()) <= tau * (1.0 + 1e-6));
    // a region crossing the smooth/turbulent seam touches blocks of
    // different sizes; only intersecting blocks decode, bound still holds
    let region: Tensor<f32> = d.decompress_region(&[8, 5], &[12, 14]).unwrap();
    let direct = t.block(&[8, 5], &[12, 14]).unwrap();
    assert!(linf_error(direct.data(), region.data()) <= tau * (1.0 + 1e-6));
}

#[test]
fn adaptive_partition_covers_exactly_and_respects_min_shape() {
    let t = synth::split_test_field(&[17, 33], 9);
    let tiles = mgardp::chunk::adaptive_partition(&[17, 33], &[4, 4], 0.3, 2, |b| {
        t.block(&b.start, &b.shape)
    })
    .unwrap();
    let mut seen = vec![0u8; 17 * 33];
    for b in &tiles {
        assert!(b.shape.iter().all(|&s| s >= 4), "tile {b:?} under min shape");
        for dz in 0..b.shape[0] {
            for dx in 0..b.shape[1] {
                seen[(b.start[0] + dz) * 33 + (b.start[1] + dx)] += 1;
            }
        }
    }
    assert!(seen.iter().all(|&c| c == 1), "overlap or gap in adaptive tiling");
}

#[test]
fn invalid_adaptive_configs_error() {
    let t = synth::smooth_test_field(&[12, 12]);
    for (min, thr) in [(vec![1usize], 0.5), (vec![4], -0.5), (vec![4], f64::NAN)] {
        let codec = MgardPlus::default().chunked(adaptive(&[8], &min, thr, 1));
        assert!(
            codec.compress(&t, Tolerance::Rel(1e-3)).is_err(),
            "min {min:?} threshold {thr} accepted"
        );
    }
}
