//! Whole-system integration: pipeline + refactor store + analysis compose
//! over the public API, and the invariants hold under the multi-threaded
//! coordinator.

use mgardp::analysis::isosurface_area_scaled;
use mgardp::compressors::{Compressor, MgardPlus, Tolerance};
use mgardp::coordinator::pipeline::{self, PipelineConfig};
use mgardp::coordinator::refactor::RefactorStore;
use mgardp::coordinator::registry::Registry;
use mgardp::data::synth;
use mgardp::decompose::{Decomposer, OptFlags};
use mgardp::grid::Hierarchy;
use mgardp::metrics::{linf_error, psnr};
use mgardp::tensor::Tensor;

#[test]
fn pipeline_honours_bounds_for_every_method() {
    let datasets = vec![synth::nyx_like(0.1, 5)];
    for method in ["sz", "zfp", "hybrid", "mgard", "mgard+"] {
        let report = pipeline::run(
            &datasets,
            &PipelineConfig {
                workers: 2,
                method: method.into(),
                tolerance: Tolerance::Rel(1e-3),
                verify: true,
                ..PipelineConfig::default()
            },
            &Registry::new(),
        )
        .unwrap();
        for r in &report.results {
            let field = datasets[0].field(&r.field).unwrap();
            let tau = 1e-3 * field.data.value_range();
            assert!(
                r.linf.unwrap() <= tau * (1.0 + 1e-6),
                "{method} {}: {} > {tau}",
                r.field,
                r.linf.unwrap()
            );
        }
    }
}

#[test]
fn refactor_then_analyze_matches_direct_analysis() {
    // the §6.2.2 workflow: refactor a field, reconstruct a coarse level,
    // run the iso-surface analysis on it, compare to full-resolution result.
    // (A smooth field stands in here; the table3_4 bench runs the NYX analog
    // at full scale, where coarse levels behave as in the paper.)
    let c = 16.0;
    let data = Tensor::<f32>::from_fn(&[33, 33, 33], |ix| {
        let dx = ix[0] as f64 - c;
        let dy = ix[1] as f64 - c;
        let dz = ix[2] as f64 - c;
        let r = (dx * dx + dy * dy + dz * dz).sqrt();
        (r - 10.0 + 1.5 * (0.4 * dx).sin() * (0.3 * dy).cos()) as f32
    });
    let dir = std::env::temp_dir().join(format!("mgardp_e2e_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = RefactorStore::create(&dir).unwrap();
    let manifest = store.write_field("velocity_x", &data, 3).unwrap();

    let full_area = isosurface_area_scaled(&data, 0.0, 1.0);
    assert!(full_area > 0.0);

    // reconstruct every level; area error should generally shrink as the
    // level rises, and the finest level must match the original closely
    // the paper's Tables 3/4 decompose 3 times (4 representation levels);
    // deeper levels of a turbulent field carry no iso-surface fidelity
    let hierarchy = Hierarchy::new(data.shape(), None).unwrap();
    let shallowest = manifest.max_level.saturating_sub(3).max(manifest.start_level);
    for level in (shallowest..=manifest.max_level).rev() {
        let rec: Tensor<f32> = store.reconstruct("velocity_x", level).unwrap();
        let h = hierarchy.spacing(level);
        let area = isosurface_area_scaled(&rec, 0.0, h);
        let rel = (area - full_area).abs() / full_area;
        if level == manifest.max_level {
            assert!(rel < 1e-3, "finest level area rel err {rel}");
        } else {
            // coarse representations keep the area in the right ballpark
            assert!(rel < 0.6, "level {level} area rel err {rel}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mgard_plus_quality_tracks_tolerance() {
    // monotonicity: smaller tolerance => higher PSNR and lower ratio
    let t = synth::smooth_test_field(&[24, 24, 24]);
    let m = MgardPlus::default();
    let mut prev_psnr = -1.0;
    let mut prev_bytes = usize::MAX;
    for rel in [1e-1, 1e-2, 1e-3, 1e-4] {
        let bytes = m.compress(&t, Tolerance::Rel(rel)).unwrap();
        let back: Tensor<f32> = m.decompress(&bytes).unwrap();
        let p = psnr(t.data(), back.data());
        assert!(p > prev_psnr, "PSNR must rise as τ falls ({p} after {prev_psnr})");
        assert!(bytes.len() >= prev_bytes.min(bytes.len()));
        prev_psnr = p;
        prev_bytes = bytes.len();
    }
}

#[test]
fn decomposition_engines_equal_on_real_fields() {
    // baseline (§2) vs optimized (§5) on an actual dataset analog field
    let ds = synth::scale_like(0.1, 9);
    let field = &ds.fields[0].data;
    let h = Hierarchy::new(field.shape(), None).unwrap();
    let slow = Decomposer::new(h.clone(), OptFlags::baseline()).unwrap();
    let fast = Decomposer::new(h, OptFlags::all()).unwrap();
    let a = slow.decompose(field).unwrap();
    let b = fast.decompose(field).unwrap();
    assert!(linf_error(a.coarse.data(), b.coarse.data()) < 1e-3);
    for (x, y) in a.coeffs.iter().zip(&b.coeffs) {
        assert!(linf_error(x, y) < 1e-3);
    }
    // cross-engine recompose
    let back = fast.recompose(&a).unwrap();
    assert!(linf_error(field.data(), back.data()) < 1e-3);
}

#[test]
fn container_cross_decompression() {
    // decompress_any dispatches on the header for every method
    let t = synth::smooth_test_field(&[14, 14, 14]);
    for method in ["sz", "zfp", "hybrid", "mgard", "mgard+"] {
        let c = pipeline::make_compressor(method).unwrap();
        let bytes = c.compress(&t, Tolerance::Rel(1e-3)).unwrap();
        let back: Tensor<f32> = mgardp::compressors::decompress_any(&bytes).unwrap();
        let tau = 1e-3 * t.value_range();
        assert!(linf_error(t.data(), back.data()) <= tau, "{method}");
    }
}
