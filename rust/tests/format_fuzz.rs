//! Serialization fuzz/property suite for the container formats: headers
//! round-trip over randomized inputs, and truncated or corrupted containers
//! (including the chunked container's block index) always return `Err` —
//! never panic, never allocate unboundedly.

use mgardp::chunk::ChunkedConfig;
use mgardp::compressors::{
    decompress_any, Compressor, Header, MgardPlus, Method, Tolerance,
};
use mgardp::data::rng::Rng;
use mgardp::data::synth;
use mgardp::tensor::Tensor;

#[test]
fn header_round_trip_randomized() {
    let mut rng = Rng::new(0xF0F0);
    let methods = [
        Method::Mgard,
        Method::MgardPlus,
        Method::Sz,
        Method::Zfp,
        Method::Hybrid,
        Method::Chunked,
    ];
    for trial in 0..200 {
        let ndim = 1 + rng.below(4);
        // dims small enough that the product stays under MAX_HEADER_NUMEL
        let shape: Vec<usize> = (0..ndim).map(|_| 2 + rng.below(90)).collect();
        let h = Header {
            method: methods[rng.below(methods.len())],
            dtype: if rng.below(2) == 0 { 1 } else { 2 },
            shape,
            tau_abs: rng.uniform_in(1e-9, 10.0),
        };
        let mut buf = Vec::new();
        h.write(&mut buf);
        let (back, _) = Header::read(&buf).unwrap();
        assert_eq!(h, back, "trial {trial}");
    }
}

#[test]
fn truncated_headers_rejected() {
    let h = Header {
        method: Method::MgardPlus,
        dtype: 1,
        shape: vec![100, 200, 300],
        tau_abs: 1e-3,
    };
    let mut buf = Vec::new();
    h.write(&mut buf);
    for cut in 0..buf.len() {
        assert!(Header::read(&buf[..cut]).is_err(), "cut at {cut}");
    }
}

#[test]
fn implausible_ndim_rejected() {
    // magic + version + method + dtype + ndim=9: the reader caps rank at 8
    let mut buf: Vec<u8> = b"MGRP".to_vec();
    buf.extend_from_slice(&[1, 2, 1, 9]);
    buf.extend_from_slice(&[5; 64]);
    assert!(Header::read(&buf).is_err());
}

fn chunked_container() -> (Tensor<f32>, Vec<u8>) {
    let t = synth::smooth_test_field(&[14, 18]);
    let codec = MgardPlus::default().chunked(ChunkedConfig {
        block_shape: vec![8],
        threads: 1,
        ..Default::default()
    });
    let bytes = codec.compress(&t, Tolerance::Rel(1e-3)).unwrap();
    (t, bytes)
}

#[test]
fn truncated_chunked_container_errors_cleanly() {
    let (_, bytes) = chunked_container();
    let codec = MgardPlus::default().chunked(ChunkedConfig {
        block_shape: vec![8],
        threads: 1,
        ..Default::default()
    });
    // every possible truncation point: must return Err, never panic
    for cut in 0..bytes.len() {
        let r: mgardp::Result<Tensor<f32>> = codec.decompress(&bytes[..cut]);
        assert!(r.is_err(), "truncation at {cut} did not error");
    }
}

#[test]
fn corrupted_chunked_index_never_panics() {
    let (_, bytes) = chunked_container();
    let codec = MgardPlus::default().chunked(ChunkedConfig {
        block_shape: vec![8],
        threads: 2,
        ..Default::default()
    });
    let mut rng = Rng::new(0xC0DE);
    // single-byte flips across the whole container, with extra density in
    // the header+index region (the first ~120 bytes)
    for trial in 0..400 {
        let mut bad = bytes.clone();
        let pos = if trial % 2 == 0 {
            rng.below(bad.len().min(120))
        } else {
            rng.below(bad.len())
        };
        bad[pos] ^= 1 << rng.below(8);
        // Err or wrong data, never panic
        let _: mgardp::Result<Tensor<f32>> = codec.decompress(&bad);
        let _: mgardp::Result<Tensor<f32>> = decompress_any(&bad);
    }
}

#[test]
fn random_garbage_never_panics() {
    let mut rng = Rng::new(0xBAD5EED);
    for _ in 0..200 {
        let n = rng.below(300);
        let junk: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let _: mgardp::Result<Tensor<f32>> = decompress_any(&junk);
        let m = MgardPlus::default();
        let _: mgardp::Result<Tensor<f32>> = m.decompress(&junk);
    }
    // valid magic, garbage after it
    for _ in 0..200 {
        let n = 4 + rng.below(120);
        let mut junk: Vec<u8> = b"MGRP".to_vec();
        junk.extend((4..n).map(|_| rng.below(256) as u8));
        let _: mgardp::Result<Tensor<f32>> = decompress_any(&junk);
    }
}

#[test]
fn truncated_final_block_is_structured_error() {
    // build a real chunked container, then re-serialize it with the final
    // blob truncated: the index still declares the full length, so the
    // declared region overruns the (shorter) blob section. The parser must
    // surface the *structured* error — pinpointing the block — for every
    // truncation depth, and the streamed reader path must agree.
    use mgardp::chunk::container::{read_container, read_index, write_container};
    use mgardp::error::Error;

    let (_, bytes) = chunked_container();
    let (header, index, blob) = read_container(&bytes).unwrap();
    let nblocks = index.entries.len();
    assert!(nblocks >= 2, "fuzz case needs a multi-block container");
    let last = index.entries.last().unwrap().clone();
    let mut rng = Rng::new(0x77121C);
    for _ in 0..50 {
        let cut = 1 + rng.below(last.len - 1);
        let mut blobs: Vec<Vec<u8>> = index
            .entries
            .iter()
            .map(|e| blob[e.offset..e.offset + e.len].to_vec())
            .collect();
        let short = blobs.last_mut().unwrap();
        short.truncate(short.len() - cut);
        let bad = write_container::<f32>(&header.shape, header.tau_abs, &index, &blobs);
        match read_container(&bad) {
            Err(Error::BlobOutOfRange {
                block,
                offset,
                len,
                section,
            }) => {
                assert_eq!(block, nblocks - 1);
                assert_eq!(offset, last.offset);
                assert_eq!(len, last.len);
                assert_eq!(section, last.offset + last.len - cut);
            }
            other => panic!("cut {cut}: expected BlobOutOfRange, got {other:?}"),
        }
        // the prefix-only parser returns the same structured error
        assert!(matches!(
            read_index(&bad),
            Err(Error::BlobOutOfRange { .. })
        ));
    }
}

fn adaptive_container() -> (Tensor<f32>, Vec<u8>) {
    let t = synth::split_test_field(&[18, 22], 21);
    let codec = MgardPlus::default().chunked(ChunkedConfig {
        block_shape: vec![8],
        threads: 1,
        tiling: mgardp::chunk::Tiling::Adaptive {
            min_block_shape: vec![4],
            variance_threshold: 0.4,
        },
    });
    let bytes = codec.compress(&t, Tolerance::Rel(1e-3)).unwrap();
    (t, bytes)
}

#[test]
fn corrupted_adaptive_sub_version_byte_errors_cleanly() {
    let (_, bytes) = adaptive_container();
    // the sub-version byte sits right after the shared header; recompute
    // the header length instead of hard-coding it
    let mut header_only = Vec::new();
    Header {
        method: Method::Chunked,
        dtype: 1,
        shape: vec![18, 22],
        tau_abs: mgardp::compressors::Header::read(&bytes).unwrap().0.tau_abs,
    }
    .write(&mut header_only);
    let pos = header_only.len();
    assert_eq!(bytes[pos], 2, "adaptive containers must declare sub-version 2");
    // unknown sub-versions are refused outright
    for bad_version in [0u8, 3, 7, 255] {
        let mut bad = bytes.clone();
        bad[pos] = bad_version;
        let r: mgardp::Result<Tensor<f32>> = decompress_any(&bad);
        assert!(r.is_err(), "sub-version {bad_version} accepted");
    }
    // flipping to sub-version 1 re-interprets the policy bytes as the block
    // count/index; whatever happens, it must not panic (and with this
    // container it fails validation)
    let mut bad = bytes.clone();
    bad[pos] = 1;
    let _: mgardp::Result<Tensor<f32>> = decompress_any(&bad);
    // every single-byte corruption of the policy region errors or decodes,
    // never panics
    let mut rng = Rng::new(0xADA9);
    for _ in 0..200 {
        let mut bad = bytes.clone();
        let p = pos + rng.below(16);
        bad[p] ^= 1 << rng.below(8);
        let _: mgardp::Result<Tensor<f32>> = decompress_any(&bad);
    }
}

#[test]
fn truncated_adaptive_container_errors_cleanly() {
    let (_, bytes) = adaptive_container();
    for cut in 0..bytes.len().min(200) {
        let r: mgardp::Result<Tensor<f32>> = decompress_any(&bytes[..cut]);
        assert!(r.is_err(), "truncation at {cut} did not error");
    }
}

fn progressive_manifest_bytes() -> Vec<u8> {
    let t = synth::smooth_test_field(&[9, 10]);
    let (m, _) = mgardp::progressive::refactor_streams(&t, 8, 3).unwrap();
    m.to_bytes()
}

#[test]
fn truncated_progressive_manifest_rejected() {
    use mgardp::progressive::ProgressiveManifest;
    let bytes = progressive_manifest_bytes();
    assert!(ProgressiveManifest::from_bytes(&bytes).is_ok());
    // every possible truncation point must error, never panic
    for cut in 0..bytes.len() {
        assert!(
            ProgressiveManifest::from_bytes(&bytes[..cut]).is_err(),
            "manifest truncation at {cut} did not error"
        );
    }
}

#[test]
fn corrupted_progressive_manifest_never_panics() {
    use mgardp::progressive::ProgressiveManifest;
    let bytes = progressive_manifest_bytes();
    let mut rng = Rng::new(0x9106);
    // single-byte flips anywhere in the manifest: Err or a manifest that
    // still passes validation — never a panic, never unbounded allocation
    for _ in 0..600 {
        let mut bad = bytes.clone();
        let pos = rng.below(bad.len());
        bad[pos] ^= 1 << rng.below(8);
        let _ = ProgressiveManifest::from_bytes(&bad);
    }
    // random garbage and truncated magic
    for _ in 0..200 {
        let n = rng.below(200);
        let junk: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        assert!(ProgressiveManifest::from_bytes(&junk).is_err());
        let mut with_magic = b"MGPR".to_vec();
        with_magic.extend((0..n).map(|_| rng.below(256) as u8));
        let _ = ProgressiveManifest::from_bytes(&with_magic);
    }
}

/// A progressive store field on disk for the component-level fuzz cases.
fn progressive_store() -> (mgardp::coordinator::refactor::RefactorStore, Tensor<f32>) {
    let dir = std::env::temp_dir().join(format!(
        "mgardp_fuzz_prog_{}_{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .subsec_nanos()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let store = mgardp::coordinator::refactor::RefactorStore::create(dir).unwrap();
    let t = synth::smooth_test_field(&[9, 10]);
    store.write_field_progressive("u", &t, Some(8), 3).unwrap();
    (store, t)
}

#[test]
fn truncated_bitplane_components_error_cleanly() {
    let (store, _) = progressive_store();
    let path = store.root().unwrap().join("u").join("components.bin");
    let blob = std::fs::read(&path).unwrap();
    // any truncation is refused at open (size vs manifest accounting)
    for cut in [0, 1, blob.len() / 2, blob.len() - 1] {
        std::fs::write(&path, &blob[..cut]).unwrap();
        assert!(store.progressive("u").is_err(), "cut {cut} accepted");
    }
    std::fs::write(&path, &blob).unwrap();
    assert!(store.progressive("u").is_ok());
    std::fs::remove_dir_all(store.root().unwrap()).ok();
}

#[test]
fn corrupted_bitplane_components_never_panic() {
    let (store, _) = progressive_store();
    let path = store.root().unwrap().join("u").join("components.bin");
    let blob = std::fs::read(&path).unwrap();
    let mut rng = Rng::new(0xB17F);
    for _ in 0..200 {
        let mut bad = blob.clone();
        let pos = rng.below(bad.len());
        bad[pos] ^= 1 << rng.below(8);
        std::fs::write(&path, &bad).unwrap();
        // same size, corrupt payload: retrieval either errors (the
        // lossless stage or component validation catches it) or yields
        // wrong-but-bounded-size data — it must never panic
        if let Ok(field) = store.progressive("u") {
            let _: mgardp::Result<(Tensor<f32>, _)> = field.retrieve(1e-3);
            let _: mgardp::Result<(Tensor<f32>, _)> = field.retrieve(f64::MIN_POSITIVE);
        }
    }
    std::fs::remove_dir_all(store.root().unwrap()).ok();
}

#[test]
fn corrupted_progressive_store_manifest_never_panics() {
    let (store, _) = progressive_store();
    let path = store.root().unwrap().join("u").join("manifest.bin");
    let bytes = std::fs::read(&path).unwrap();
    let mut rng = Rng::new(0x5106);
    for _ in 0..300 {
        let mut bad = bytes.clone();
        let pos = rng.below(bad.len());
        bad[pos] ^= 1 << rng.below(8);
        std::fs::write(&path, &bad).unwrap();
        // opening revalidates the manifest *and* its byte accounting
        // against components.bin, so a flipped length is caught here
        if let Ok(field) = store.progressive("u") {
            let _: mgardp::Result<(Tensor<f32>, _)> = field.retrieve(1e-2);
        }
    }
    std::fs::remove_dir_all(store.root().unwrap()).ok();
}

#[test]
fn legacy_level_manifest_fuzz_never_panics() {
    use mgardp::coordinator::refactor::RefactorStore;
    let dir = std::env::temp_dir().join(format!("mgardp_fuzz_lvl_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = RefactorStore::create(&dir).unwrap();
    let t = synth::smooth_test_field(&[9, 9]);
    store.write_field("u", &t, 3).unwrap();
    let path = dir.join("u").join("manifest.bin");
    let bytes = std::fs::read(&path).unwrap();
    let mut rng = Rng::new(0x1EE7);
    for cut in 0..bytes.len() {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        assert!(store.manifest("u").is_err(), "cut {cut} accepted");
    }
    for _ in 0..300 {
        let mut bad = bytes.clone();
        let pos = rng.below(bad.len());
        bad[pos] ^= 1 << rng.below(8);
        std::fs::write(&path, &bad).unwrap();
        if store.manifest("u").is_ok() {
            // a still-valid manifest must also still reconstruct or error
            // cleanly (no panic on mismatched component files)
            let _: mgardp::Result<Tensor<f32>> = store.reconstruct("u", 0);
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------------------ MGSH shards

/// A realistic components-kind shard: 12 variable-length components
/// across 3 streams.
fn sample_shard() -> Vec<u8> {
    let mut w = mgardp::shard::ShardWriter::components();
    let mut rng = Rng::new(0x5AAD);
    for comp in 0..12usize {
        let n = 1 + rng.below(40);
        let bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        w.push_component(comp / 4, comp % 4, 1.0 / (comp as f64 + 1.0), &bytes)
            .unwrap();
    }
    w.finish().unwrap()
}

#[test]
fn truncated_shard_objects_rejected() {
    use mgardp::shard::read_shard;
    let bytes = sample_shard();
    assert!(read_shard(&bytes).is_ok());
    // every possible truncation point must error, never panic
    for cut in 0..bytes.len() {
        assert!(read_shard(&bytes[..cut]).is_err(), "cut at {cut}");
    }
}

#[test]
fn corrupted_shard_objects_never_panic_or_pass_with_bad_geometry() {
    use mgardp::shard::read_shard;
    let bytes = sample_shard();
    let mut rng = Rng::new(0x5D0C);
    for _ in 0..2000 {
        let mut bad = bytes.clone();
        let pos = rng.below(bad.len());
        bad[pos] ^= 1 << rng.below(8);
        // Err or a shard whose index still tiles the payload: a parse
        // that succeeds structurally cannot contain overlapping, gapped
        // or out-of-extent inner ranges
        if let Ok((index, payload)) = read_shard(&bad) {
            let mut expect = 0u64;
            for i in 0..index.len() {
                let (offset, len) = index.range(i);
                assert_eq!(offset, expect, "surviving index overlaps or gaps");
                expect = offset + len;
            }
            assert_eq!(expect, payload.len() as u64);
        }
    }
}

#[test]
fn random_inner_index_geometries_must_tile_or_be_rejected() {
    // hand-encoded components indexes with randomized (offset, len)
    // geometry: `read_index` accepts exactly the contiguous tilings of
    // the declared payload and refuses everything else — overlap, gap,
    // nonzero first offset, short or long coverage
    use mgardp::shard::read_index;
    let mut rng = Rng::new(0x6E0D);
    for trial in 0..800 {
        let n = 1 + rng.below(6);
        let mut index = vec![2u8, n as u8]; // kind = components, N (< 128)
        let mut ranges = Vec::new();
        for i in 0..n {
            let offset = rng.below(100) as u64;
            let len = rng.below(60) as u64;
            // all fields < 128, so each is a single varint byte
            index.extend_from_slice(&[i as u8, i as u8, offset as u8, len as u8]);
            index.extend_from_slice(&0.5f64.to_le_bytes());
            ranges.push((offset, len));
        }
        let payload_len = (80 + rng.below(60)) as u64;
        let tiles = {
            let mut expect = 0u64;
            let mut ok = true;
            for &(o, l) in &ranges {
                if o != expect {
                    ok = false;
                    break;
                }
                expect = o + l;
            }
            ok && expect == payload_len
        };
        assert_eq!(
            read_index(&index, payload_len).is_ok(),
            tiles,
            "trial {trial}: ranges {ranges:?} over payload {payload_len}"
        );
    }
}

#[test]
fn hostile_shard_refused_at_open_with_no_payload_reads() {
    // an overlapping inner index sealed with a perfectly well-formed
    // footer: the partial decoder must refuse it at open time, after
    // exactly its three metadata reads (size, footer tail, index) and
    // zero payload reads
    use mgardp::shard::{ShardPartialDecoder, SHARD_MAGIC, SHARD_VERSION};
    use mgardp::storage::{MemoryStorage, MockStorage, Storage};
    use std::sync::Arc;
    let payload = vec![0u8; 10];
    let mut index = vec![2u8, 2]; // kind = components, N = 2
    // entry 0 covers [0, 6), entry 1 [4, 10): overlap, yet total = 10
    for &(s, c, o, l) in &[(0u8, 0u8, 0u8, 6u8), (0, 1, 4, 6)] {
        index.extend_from_slice(&[s, c, o, l]);
        index.extend_from_slice(&0.5f64.to_le_bytes());
    }
    let mut object = payload;
    let index_off = object.len() as u64;
    object.extend_from_slice(&index);
    object.extend_from_slice(&index_off.to_le_bytes());
    object.extend_from_slice(&(index.len() as u64).to_le_bytes());
    object.push(SHARD_VERSION);
    object.extend_from_slice(SHARD_MAGIC);
    let mem = Arc::new(MemoryStorage::new());
    mem.write("s/shard_00000.mgsh", &object).unwrap();
    let mock = Arc::new(MockStorage::new(mem, std::time::Duration::ZERO, 0));
    let opened = ShardPartialDecoder::open(
        Arc::clone(&mock) as Arc<dyn Storage>,
        "s/shard_00000.mgsh",
    );
    assert!(opened.is_err(), "overlapping inner index accepted");
    assert_eq!(mock.ops(), 3, "hostile payload was read");
}

#[test]
fn oversized_counts_do_not_allocate() {
    // a chunked container whose block count field claims 2^40 blocks must be
    // rejected by the plausibility bound, not die in Vec::with_capacity
    let (_, bytes) = chunked_container();
    // the count sits right after header(4+1+1+1+1+ndim varints+8) + version
    // + inner tag + block shape; rather than compute the exact offset, flip
    // every early byte to 0xFF and require no panic
    let codec = MgardPlus::default().chunked(ChunkedConfig {
        block_shape: vec![8],
        threads: 1,
        ..Default::default()
    });
    for pos in 0..bytes.len().min(64) {
        let mut bad = bytes.clone();
        bad[pos] = 0xFF;
        let _: mgardp::Result<Tensor<f32>> = codec.decompress(&bad);
    }
}
