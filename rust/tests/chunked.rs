//! The chunked parallel pipeline's contract: block-parallel compression is
//! transparent — same error bound as the unchunked path (including across
//! block seams), bit-exact on single-block inputs, correct on remainder
//! block shapes, and deterministic under any thread count.

use mgardp::chunk::{partition, ChunkedCompressor, ChunkedConfig};
use mgardp::compressors::{decompress_any, Compressor, MgardPlus, Tolerance};
use mgardp::data::synth;
use mgardp::metrics::linf_error;
use mgardp::tensor::Tensor;

fn chunked(block: &[usize], threads: usize) -> ChunkedCompressor<MgardPlus> {
    MgardPlus::default().chunked(ChunkedConfig {
        block_shape: block.to_vec(),
        threads,
        ..Default::default()
    })
}

#[test]
fn single_block_bit_exact_vs_unchunked() {
    // a field smaller than the block shape is one block compressed at the
    // same absolute tolerance the unchunked path resolves, so the two
    // reconstructions must agree bit for bit
    let t = synth::smooth_test_field(&[14, 15, 16]);
    let tol = Tolerance::Rel(1e-3);
    let unchunked = MgardPlus::default();
    let plain: Tensor<f32> = unchunked.decompress(&unchunked.compress(&t, tol).unwrap()).unwrap();
    let codec = chunked(&[64], 2);
    let blocked: Tensor<f32> = codec.decompress(&codec.compress(&t, tol).unwrap()).unwrap();
    assert_eq!(plain.shape(), blocked.shape());
    assert_eq!(plain.data(), blocked.data(), "single-block output must be bit-exact");
}

#[test]
fn linf_bound_holds_across_block_seams() {
    // a field with structure crossing every seam of a 16³ tiling
    let t = Tensor::<f32>::from_fn(&[33, 33, 33], |ix| {
        ((ix[0] as f32) * 0.37).sin()
            + ((ix[1] as f32) * 0.23).cos() * ((ix[2] as f32) * 0.31).sin()
    });
    for rel in [1e-1, 1e-2, 1e-3, 1e-4] {
        let tau = rel * t.value_range();
        let codec = chunked(&[16], 4);
        let bytes = codec.compress(&t, Tolerance::Rel(rel)).unwrap();
        let back: Tensor<f32> = codec.decompress(&bytes).unwrap();
        let err = linf_error(t.data(), back.data());
        assert!(
            err <= tau * (1.0 + 1e-6),
            "rel {rel}: chunked L∞ {err} > τ {tau}"
        );
    }
}

#[test]
fn remainder_block_shapes() {
    // 17×33×65 with 16³ blocks exercises merged (17), merged-tail (16+17)
    // and multi-block (16+16+16+17) dimensions in one field
    let t = synth::smooth_test_field(&[17, 33, 65]);
    let blocks = partition(&[17, 33, 65], &[16, 16, 16]).unwrap();
    assert_eq!(blocks.len(), 8); // 1 × 2 × 4 blocks along the three dims
    let codec = chunked(&[16], 4);
    let bytes = codec.compress(&t, Tolerance::Rel(1e-3)).unwrap();
    let back: Tensor<f32> = codec.decompress(&bytes).unwrap();
    assert_eq!(back.shape(), &[17, 33, 65]);
    let tau = 1e-3 * t.value_range();
    assert!(linf_error(t.data(), back.data()) <= tau * (1.0 + 1e-6));
}

#[test]
fn thread_counts_agree_bitwise() {
    // the container must be a pure function of (data, tolerance, blocks):
    // worker scheduling may not leak into the output
    let t = synth::smooth_test_field(&[25, 26, 27]);
    let reference = chunked(&[12], 1).compress(&t, Tolerance::Rel(1e-3)).unwrap();
    for threads in [2, 8] {
        let bytes = chunked(&[12], threads)
            .compress(&t, Tolerance::Rel(1e-3))
            .unwrap();
        assert_eq!(bytes, reference, "{threads} threads changed the container");
        let back: Tensor<f32> = chunked(&[12], threads).decompress(&bytes).unwrap();
        let tau = 1e-3 * t.value_range();
        assert!(linf_error(t.data(), back.data()) <= tau * (1.0 + 1e-6));
    }
}

#[test]
fn concurrency_smoke_many_rounds() {
    // hammer the pool a little: repeated compress/decompress at 8 threads
    // over a block grid larger than the thread count
    let t = synth::smooth_test_field(&[40, 40, 40]);
    let codec = chunked(&[8], 8);
    let tau = 1e-2 * t.value_range();
    for _ in 0..3 {
        let bytes = codec.compress(&t, Tolerance::Rel(1e-2)).unwrap();
        let back: Tensor<f32> = codec.decompress(&bytes).unwrap();
        assert!(linf_error(t.data(), back.data()) <= tau * (1.0 + 1e-6));
    }
}

#[test]
fn dispatches_through_decompress_any() {
    let t = synth::smooth_test_field(&[20, 24]);
    let bytes = chunked(&[10, 12], 2).compress(&t, Tolerance::Rel(1e-3)).unwrap();
    let back: Tensor<f32> = decompress_any(&bytes).unwrap();
    let tau = 1e-3 * t.value_range();
    assert!(linf_error(t.data(), back.data()) <= tau * (1.0 + 1e-6));
}

#[test]
fn f64_and_other_inner_codecs() {
    let t = Tensor::<f64>::from_fn(&[19, 21], |ix| {
        ((ix[0] as f64) * 0.4).sin() * ((ix[1] as f64) * 0.3).cos()
    });
    let codec = ChunkedCompressor::new(
        MgardPlus::default(),
        ChunkedConfig {
            block_shape: vec![8],
            threads: 2,
            ..Default::default()
        },
    );
    let bytes = codec.compress(&t, Tolerance::Abs(1e-6)).unwrap();
    let back: Tensor<f64> = codec.decompress(&bytes).unwrap();
    assert!(linf_error(t.data(), back.data()) <= 1e-6);

    let t32 = synth::smooth_test_field(&[18, 18]);
    let zfp = ChunkedCompressor::new(
        mgardp::compressors::Zfp::default(),
        ChunkedConfig {
            block_shape: vec![9],
            threads: 2,
            ..Default::default()
        },
    );
    let bytes = zfp.compress(&t32, Tolerance::Rel(1e-3)).unwrap();
    let back: Tensor<f32> = zfp.decompress(&bytes).unwrap();
    let tau = 1e-3 * t32.value_range();
    assert!(linf_error(t32.data(), back.data()) <= tau * (1.0 + 1e-6));
}

#[test]
fn constant_field_and_tiny_blocks() {
    let t = Tensor::<f32>::from_fn(&[10, 10, 10], |_| 2.5);
    let codec = chunked(&[4], 2);
    let bytes = codec.compress(&t, Tolerance::Rel(1e-3)).unwrap();
    let back: Tensor<f32> = codec.decompress(&bytes).unwrap();
    // degenerate range: Tolerance::Rel falls back to unit range
    assert!(linf_error(t.data(), back.data()) <= 1e-3);
}
